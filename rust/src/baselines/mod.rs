//! Baseline platform models (§VI-D comparisons).
//!
//! The host-CPU baseline is **measured** (the JAX→HLO artifacts run
//! through [`crate::runtime`]). The GPU/TPU and prior-accelerator
//! baselines are *mechanistic analytical models*: each platform is a
//! small set of published parameters (lanes, clock, bandwidth, launch
//! overhead, sampler type) and the throughput comes from the same
//! three-phase accounting the paper uses (distribution computing,
//! sampling, memory — §II-C), so the *shape* of Fig. 14/15 (who wins,
//! crossovers with distribution size, GPU collapse on irregular
//! graphs) is reproduced from mechanisms rather than hard-coded.
//! Paper-reported ratios are kept alongside in `bench/` tables for
//! comparison. See DESIGN.md §4.

use crate::energy::EnergyModel;
use crate::mcmc::AlgoKind;
use crate::sim::su::CdfSuModel;

/// What sampler hardware a platform uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerHw {
    /// Software inverse-transform (exp + normalize + scan) on the
    /// general-purpose cores.
    Software,
    /// Dedicated sequential CDF sampler unit (SPU/PGMA/CoopMC class).
    CdfUnit {
        /// CDT register-file capacity (max supported distribution).
        capacity: usize,
    },
    /// MC²A-style pipelined Gumbel unit (for completeness).
    GumbelUnit,
    /// Per-RV probabilistic bit (sIM class): only 2-state RVs.
    PBit,
}

/// An analytical baseline platform.
#[derive(Clone, Debug)]
pub struct BaselineModel {
    /// Display name.
    pub name: &'static str,
    /// Core clock in Hz.
    pub clock_hz: f64,
    /// Parallel update lanes usable for RV updates.
    pub lanes: f64,
    /// Arithmetic ops per lane per cycle (issue width × FMA).
    pub ops_per_lane_cycle: f64,
    /// Memory bandwidth in bytes/s.
    pub mem_bw: f64,
    /// Fixed overhead per MCMC step (kernel launches, sync), seconds.
    pub step_overhead_s: f64,
    /// Software overhead ops per RV update (framework bookkeeping,
    /// index arithmetic, RNG state management — large on CPUs running
    /// interpreted/JIT frameworks, zero on fixed-function ASICs).
    pub update_overhead_ops: f64,
    /// Utilization multiplier on irregular (pointer-chasing) workloads.
    pub irregular_utilization: f64,
    /// Sampler hardware.
    pub sampler: SamplerHw,
    /// TDP in watts (Fig. 15 energy efficiency).
    pub tdp_watts: f64,
}

/// A workload's shape as the baseline models consume it.
#[derive(Clone, Copy, Debug)]
pub struct BaselineWorkload {
    /// RV updates per MCMC step.
    pub updates_per_step: f64,
    /// Conditionally-independent updates available per phase
    /// (RV-level parallelism — Fig. 4).
    pub parallelism: f64,
    /// Arithmetic ops per update (distribution computing).
    pub ops_per_update: f64,
    /// Bytes moved per update.
    pub bytes_per_update: f64,
    /// Categorical distribution size per sample.
    pub dist_size: f64,
    /// Irregular memory-access pattern (Bayes nets, ER/social graphs).
    pub irregular: bool,
}

impl BaselineWorkload {
    /// Derive the shape from a model + algorithm (same accounting as
    /// [`crate::roofline::WorkloadProfile`]).
    pub fn from_model(model: &dyn EnergyModel, algo: AlgoKind, irregular: bool) -> Self {
        let n = model.num_vars();
        let mut ops = 0f64;
        let mut bytes = 0f64;
        let mut dist = 0f64;
        for i in 0..n {
            let c = model.update_cost(i);
            ops += c.ops as f64;
            bytes += c.bytes as f64;
            dist += model.num_states(i) as f64;
        }
        let (updates, parallelism, dist_size) = match algo {
            AlgoKind::Pas => {
                // ΔE pass over all vars + L index samples from the full
                // move table; parallel across vars.
                (n as f64, n as f64, dist)
            }
            AlgoKind::BlockGibbs => {
                let coloring = crate::graph::color_greedy(model.interaction());
                let max_block = coloring
                    .blocks()
                    .iter()
                    .map(|b| b.len())
                    .max()
                    .unwrap_or(1);
                (n as f64, max_block as f64, dist / n as f64)
            }
            AlgoKind::AsyncGibbs => (n as f64, n as f64, dist / n as f64),
            AlgoKind::Gibbs | AlgoKind::Mh => (n as f64, 1.0, dist / n as f64),
        };
        BaselineWorkload {
            updates_per_step: updates,
            parallelism,
            ops_per_update: ops / n as f64,
            bytes_per_update: bytes / n as f64,
            dist_size,
            irregular,
        }
    }
}

impl BaselineModel {
    /// Seconds to draw one categorical sample on this platform.
    fn sample_seconds(&self, dist: f64) -> Option<f64> {
        match self.sampler {
            SamplerHw::Software => {
                // exp + cumsum + search ≈ 5 ops/bin on a single lane.
                Some(5.0 * dist / (self.ops_per_lane_cycle * self.clock_hz))
            }
            SamplerHw::CdfUnit { capacity } => {
                if dist > capacity as f64 {
                    return None; // unsupported distribution size
                }
                let c = CdfSuModel {
                    cdt_capacity: capacity,
                    exp_latency: 1,
                };
                Some(c.sample_cost(dist as usize).cycles as f64 / self.clock_hz)
            }
            SamplerHw::GumbelUnit => Some(dist / self.clock_hz),
            SamplerHw::PBit => {
                if dist > 2.0 {
                    None // Ising machines: binary RVs only
                } else {
                    Some(1.0 / self.clock_hz)
                }
            }
        }
    }

    /// Predicted throughput in Giga-samples (RV updates) per second.
    /// Returns 0 when the platform cannot run the workload at all.
    pub fn throughput_gsps(&self, w: &BaselineWorkload) -> f64 {
        let util = if w.irregular {
            self.irregular_utilization
        } else {
            1.0
        };
        // Distribution computing: parallel across min(lanes, parallelism).
        let eff_lanes = self.lanes.min(w.parallelism).max(1.0);
        let compute_s = w.updates_per_step * (w.ops_per_update + self.update_overhead_ops)
            / (eff_lanes * self.ops_per_lane_cycle * self.clock_hz * util);
        // Memory phase.
        let mem_s = w.updates_per_step * w.bytes_per_update / (self.mem_bw * util);
        // Sampling phase: serial per lane-group (the §III observation:
        // "bottleneck of sequential sampling operations").
        let per_sample = match self.sample_seconds(w.dist_size) {
            Some(s) => s,
            None => return 0.0,
        };
        let sample_lanes = match self.sampler {
            SamplerHw::Software => eff_lanes, // each core samples its own RVs
            _ => 1.0,                         // one sampler unit
        };
        let sample_s = w.updates_per_step * per_sample / sample_lanes;
        let step_s = compute_s.max(mem_s) + sample_s + self.step_overhead_s;
        w.updates_per_step / step_s / 1e9
    }

    /// Fig. 15 metric: GS/s per watt (TDP-based, like the paper).
    pub fn gsps_per_watt(&self, w: &BaselineWorkload) -> f64 {
        self.throughput_gsps(w) / self.tdp_watts
    }
}

/// Xeon-class CPU (single socket, the paper's software baseline).
pub fn cpu_xeon() -> BaselineModel {
    BaselineModel {
        name: "CPU (Xeon)",
        clock_hz: 3.0e9,
        lanes: 16.0,
        ops_per_lane_cycle: 4.0, // scalar+SIMD mix on irregular code
        mem_bw: 100e9,
        step_overhead_s: 2e-6, // loop + allocator overhead per step
        // Calibrated against the *measured* JAX/XLA-CPU path on this
        // host (EXPERIMENTS.md): ~16 ns per RV update on the Ising
        // sweep — frameworks spend the overwhelming majority of
        // per-update time outside the ~10 useful flops.
        update_overhead_ops: 3000.0,
        irregular_utilization: 0.5, // caches handle pointer chasing well
        sampler: SamplerHw::Software,
        tdp_watts: 120.0,
    }
}

/// RTX-2080Ti-class GPU (the paper's Fig. 5d / Fig. 14 GPU).
pub fn gpu_rtx() -> BaselineModel {
    BaselineModel {
        name: "GPU (RTX)",
        clock_hz: 1.5e9,
        lanes: 4352.0,
        ops_per_lane_cycle: 2.0,
        mem_bw: 616e9,
        step_overhead_s: 50e-6, // kernel launches + host sync per step
        update_overhead_ops: 10.0,
        irregular_utilization: 0.02, // uncoalesced gathers collapse SIMT
        sampler: SamplerHw::Software,
        tdp_watts: 250.0,
    }
}

/// V100-class GPU (the structured-graph comparison of §VI-D).
pub fn gpu_v100() -> BaselineModel {
    BaselineModel {
        name: "GPU (V100)",
        clock_hz: 1.4e9,
        lanes: 5120.0,
        ops_per_lane_cycle: 2.0,
        mem_bw: 900e9,
        step_overhead_s: 40e-6,
        update_overhead_ops: 10.0,
        irregular_utilization: 0.02,
        sampler: SamplerHw::Software,
        tdp_watts: 250.0,
    }
}

/// TPU-v3 single core.
pub fn tpu_v3() -> BaselineModel {
    BaselineModel {
        name: "TPU-v3",
        clock_hz: 0.94e9,
        lanes: 2048.0, // one MXU's effective parallel lanes for elementwise
        ops_per_lane_cycle: 2.0,
        mem_bw: 450e9,
        step_overhead_s: 60e-6, // dispatch + infeed per step
        update_overhead_ops: 10.0,
        irregular_utilization: 0.01, // gather-hostile systolic datapath
        sampler: SamplerHw::Software,
        tdp_watts: 100.0,
    }
}

/// SPU (ASPLOS'21): chessboard MRF accelerator with CDF samplers.
pub fn spu() -> BaselineModel {
    BaselineModel {
        name: "SPU",
        clock_hz: 1.0e9,
        lanes: 64.0,
        ops_per_lane_cycle: 1.0,
        mem_bw: 128e9,
        step_overhead_s: 0.0,
        update_overhead_ops: 0.0,
        irregular_utilization: 0.1, // fixed datapath: structured graphs only
        sampler: SamplerHw::CdfUnit { capacity: 128 },
        tdp_watts: 2.0,
    }
}

/// PGMA (VLSI'20): 16 nm Gibbs-sampling PGM accelerator.
pub fn pgma() -> BaselineModel {
    BaselineModel {
        name: "PGMA",
        clock_hz: 0.5e9,
        lanes: 4.0,
        ops_per_lane_cycle: 1.0,
        mem_bw: 16e9,
        step_overhead_s: 0.0,
        update_overhead_ops: 0.0,
        irregular_utilization: 0.8,
        sampler: SamplerHw::CdfUnit { capacity: 64 },
        tdp_watts: 0.1,
    }
}

/// CoopMC (HPCA'22): tree-CDF sampler co-optimized MCMC accelerator.
pub fn coopmc() -> BaselineModel {
    BaselineModel {
        name: "CoopMC",
        clock_hz: 1.0e9,
        lanes: 16.0,
        ops_per_lane_cycle: 1.0,
        mem_bw: 64e9,
        step_overhead_s: 0.0,
        update_overhead_ops: 0.0,
        irregular_utilization: 0.5,
        sampler: SamplerHw::CdfUnit { capacity: 256 },
        tdp_watts: 1.0,
    }
}

/// sIM (Nature Electronics'22): sparse Ising machine (p-bits).
pub fn sparse_ising_machine() -> BaselineModel {
    BaselineModel {
        name: "sIM",
        clock_hz: 0.1e9,
        lanes: 1024.0,
        ops_per_lane_cycle: 1.0,
        mem_bw: 32e9,
        step_overhead_s: 0.0,
        update_overhead_ops: 0.0,
        irregular_utilization: 0.8,
        sampler: SamplerHw::PBit,
        tdp_watts: 1.0,
    }
}

/// PROCA (HPCA'25): programmable probabilistic processing unit.
pub fn proca() -> BaselineModel {
    BaselineModel {
        name: "PROCA",
        clock_hz: 1.0e9,
        lanes: 8.0, // one core per RV, vector RISC-V compute
        ops_per_lane_cycle: 2.0,
        mem_bw: 64e9,
        step_overhead_s: 0.0,
        update_overhead_ops: 4.0,
        irregular_utilization: 0.6,
        sampler: SamplerHw::GumbelUnit, // supports any distribution size
        tdp_watts: 1.5,
    }
}

/// All ASIC baselines.
pub fn all_accelerators() -> Vec<BaselineModel> {
    vec![spu(), pgma(), coopmc(), sparse_ising_machine(), proca()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::PottsGrid;
    use crate::workloads;

    fn mrf_workload() -> BaselineWorkload {
        let m = PottsGrid::new(387, 388, 2, 1.0); // paper-scale MRF
        BaselineWorkload::from_model(&m, AlgoKind::BlockGibbs, false)
    }

    fn bayesnet_workload() -> BaselineWorkload {
        let wl = workloads::wl_survey();
        BaselineWorkload::from_model(wl.model.as_ref(), AlgoKind::BlockGibbs, true)
    }

    #[test]
    fn gpu_beats_cpu_on_structured_mrf() {
        // §VI-D: "For structured graphs like 2D-grid MRF, the GPU and
        // TPU show better performance than the CPU."
        let w = mrf_workload();
        assert!(gpu_v100().throughput_gsps(&w) > cpu_xeon().throughput_gsps(&w));
        assert!(tpu_v3().throughput_gsps(&w) > cpu_xeon().throughput_gsps(&w));
    }

    #[test]
    fn cpu_beats_gpu_on_irregular_bayes_nets() {
        // §VI-D observation ①/②: GPUs collapse on tiny irregular nets.
        let w = bayesnet_workload();
        assert!(
            cpu_xeon().throughput_gsps(&w) > gpu_rtx().throughput_gsps(&w) * 10.0,
            "cpu={} gpu={}",
            cpu_xeon().throughput_gsps(&w),
            gpu_rtx().throughput_gsps(&w)
        );
    }

    #[test]
    fn cdf_accelerators_fail_large_distributions() {
        // Fig. 13 / §VI-D: CDF-based designs cap the distribution size.
        let mut w = mrf_workload();
        w.dist_size = 256.0;
        assert_eq!(pgma().throughput_gsps(&w), 0.0);
        assert_eq!(spu().throughput_gsps(&w), 0.0);
        assert!(coopmc().throughput_gsps(&w) > 0.0); // capacity 256
        assert!(proca().throughput_gsps(&w) > 0.0); // any size
    }

    #[test]
    fn ising_machine_only_handles_binary() {
        let mut w = mrf_workload();
        w.dist_size = 4.0; // Potts with 4 labels
        assert_eq!(sparse_ising_machine().throughput_gsps(&w), 0.0);
        w.dist_size = 2.0;
        assert!(sparse_ising_machine().throughput_gsps(&w) > 0.0);
    }

    #[test]
    fn energy_efficiency_ordering() {
        // Fig. 15: ASIC efficiency ≫ GPU ≫ CPU on structured graphs.
        let w = mrf_workload();
        let cpu = cpu_xeon().gsps_per_watt(&w);
        let gpu = gpu_v100().gsps_per_watt(&w);
        let asic = coopmc().gsps_per_watt(&w);
        assert!(gpu > cpu, "gpu {gpu} vs cpu {cpu}");
        assert!(asic > gpu, "asic {asic} vs gpu {gpu}");
    }

    #[test]
    fn workload_shapes_by_algorithm() {
        let m = PottsGrid::new(8, 8, 2, 1.0);
        let seq = BaselineWorkload::from_model(&m, AlgoKind::Gibbs, false);
        let bg = BaselineWorkload::from_model(&m, AlgoKind::BlockGibbs, false);
        assert_eq!(seq.parallelism, 1.0);
        assert_eq!(bg.parallelism, 32.0); // chessboard half
        let pas = BaselineWorkload::from_model(&m, AlgoKind::Pas, false);
        assert_eq!(pas.dist_size, 128.0); // full move table
    }
}
