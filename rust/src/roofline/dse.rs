//! Design-space exploration over the accelerator parameters (§VI-B,
//! Fig. 11): sweep (T, K, S, M, B), evaluate every benchmark workload
//! on the 3D roofline, and pick the configuration that maximizes the
//! worst-case (min-normalized) throughput under an area budget.
//!
//! Area is modeled the way the paper reasons about "total hardware
//! resource budget": CU area ∝ T·2^K PE adders, SU area ∝ S
//! comparators + LUTs, memory area ∝ B ports + the fixed 4.8 MB SRAM.

use super::{evaluate, WorkloadProfile};
use crate::isa::HwConfig;

/// One candidate configuration with its DSE score.
#[derive(Clone, Debug)]
pub struct DseCandidate {
    /// The hardware parameters.
    pub hw: HwConfig,
    /// Relative area cost (arbitrary units).
    pub area: f64,
    /// Per-workload predicted throughput (GS/s), same order as input.
    pub tp: Vec<f64>,
    /// Geometric-mean throughput across workloads.
    pub geomean_tp: f64,
    /// Minimum normalized throughput (vs the best config per workload).
    pub min_norm: f64,
}

/// Result of a DSE sweep.
#[derive(Clone, Debug)]
pub struct DseResult {
    /// All evaluated candidates (area-feasible ones).
    pub candidates: Vec<DseCandidate>,
    /// Index of the selected candidate in `candidates`.
    pub chosen: usize,
}

/// Relative area model.
pub fn area_units(hw: &HwConfig) -> f64 {
    let cu = (hw.t * (1 << hw.k)) as f64; // adder tree nodes
    let su = hw.s as f64 * 1.5; // comparator + LUT share
    let mem = hw.bw_words as f64 * 2.0; // port + wiring cost
    cu + su + mem
}

/// Sweep the parameter grid and choose the best configuration under
/// `area_budget` (units of [`area_units`]).
///
/// Selection criterion: maximize the geometric-mean predicted
/// throughput across `workloads`, breaking ties toward smaller area —
/// the paper's "push the spatial-mode roof apex toward these workloads
/// while keeping the temporal workloads at full utilization".
pub fn dse_sweep(workloads: &[WorkloadProfile], area_budget: f64) -> DseResult {
    let t_opts = [16usize, 32, 64, 128];
    let k_opts = [1usize, 2, 3, 4];
    let m_opts = [4usize, 5, 6, 7];
    let b_opts = [80usize, 160, 320, 640];

    let mut candidates = Vec::new();
    for &t in &t_opts {
        for &k in &k_opts {
            for &m in &m_opts {
                for &b in &b_opts {
                    let hw = HwConfig {
                        t,
                        k,
                        s: 1 << m,
                        m,
                        bw_words: b,
                        clock_ghz: 0.5,
                        rf_banks: t.max(16),
                        rf_regs_per_bank: 2 * (1 << k),
                        lut_size: 16,
                        lut_bits: 8,
                        max_dist_size: 256,
                    };
                    let area = area_units(&hw);
                    if area > area_budget {
                        continue;
                    }
                    let tp: Vec<f64> = workloads
                        .iter()
                        .map(|w| evaluate(&hw, w).tp_gsps)
                        .collect();
                    let geomean_tp = (tp.iter().map(|v| v.max(1e-12).ln()).sum::<f64>()
                        / tp.len().max(1) as f64)
                        .exp();
                    candidates.push(DseCandidate {
                        hw,
                        area,
                        tp,
                        geomean_tp,
                        min_norm: 0.0,
                    });
                }
            }
        }
    }
    assert!(!candidates.is_empty(), "area budget admits no config");

    // Normalize per workload against the best achieved TP.
    let nw = workloads.len();
    for wi in 0..nw {
        let best = candidates
            .iter()
            .map(|c| c.tp[wi])
            .fold(f64::MIN_POSITIVE, f64::max);
        for c in &mut candidates {
            let norm = c.tp[wi] / best;
            if wi == 0 || norm < c.min_norm {
                c.min_norm = norm;
            }
        }
    }

    let chosen = candidates
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| {
            (a.geomean_tp, -a.area)
                .partial_cmp(&(b.geomean_tp, -b.area))
                .unwrap()
        })
        .map(|(i, _)| i)
        .unwrap();
    DseResult { candidates, chosen }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcmc::AlgoKind;
    use crate::workloads;

    fn bench_profiles() -> Vec<WorkloadProfile> {
        workloads::suite_small()
            .iter()
            .map(|wl| WorkloadProfile::from_model(wl.model.as_ref(), wl.algorithm))
            .collect()
    }

    #[test]
    fn sweep_selects_within_budget() {
        let ws = bench_profiles();
        let budget = area_units(&HwConfig::paper_default()) * 1.05;
        let res = dse_sweep(&ws, budget);
        let c = &res.candidates[res.chosen];
        assert!(c.area <= budget);
        assert!(c.geomean_tp > 0.0);
    }

    #[test]
    fn paper_config_is_near_optimal_at_its_budget() {
        // §VI-B: at the paper's budget, the swept optimum should be the
        // paper's own configuration (or within a few % of it).
        let ws = bench_profiles();
        let paper = HwConfig::paper_default();
        let budget = area_units(&paper) * 1.01;
        let res = dse_sweep(&ws, budget);
        let chosen = &res.candidates[res.chosen];
        let paper_tp: Vec<f64> = ws.iter().map(|w| evaluate(&paper, w).tp_gsps).collect();
        let paper_geo = (paper_tp.iter().map(|v| v.max(1e-12).ln()).sum::<f64>()
            / paper_tp.len() as f64)
            .exp();
        assert!(
            chosen.geomean_tp >= paper_geo * 0.99,
            "sweep found {} vs paper {}",
            chosen.geomean_tp,
            paper_geo
        );
        // And the paper config itself must not be far off the optimum.
        assert!(
            paper_geo >= chosen.geomean_tp * 0.5,
            "paper config badly suboptimal: {} vs {}",
            paper_geo,
            chosen.geomean_tp
        );
    }

    #[test]
    fn bigger_budget_never_hurts() {
        let ws = bench_profiles();
        let small = dse_sweep(&ws, 800.0);
        let big = dse_sweep(&ws, 3000.0);
        assert!(
            big.candidates[big.chosen].geomean_tp
                >= small.candidates[small.chosen].geomean_tp - 1e-12
        );
    }

    #[test]
    fn profiles_cover_both_su_modes() {
        let ws = bench_profiles();
        assert!(ws.iter().any(|w| w.spatial));
        assert!(ws.iter().any(|w| !w.spatial));
    }

    #[test]
    #[should_panic(expected = "area budget admits no config")]
    fn empty_budget_panics() {
        let ws = bench_profiles();
        dse_sweep(&ws, 1.0);
    }
}
