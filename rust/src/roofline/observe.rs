//! The measured side of the 3D roofline: where a run *actually* was
//! bound, projected onto the same axes as the a-priori
//! [`evaluate`](super::evaluate) prediction.
//!
//! [`super`] answers "where *should* this workload sit on this
//! hardware"; this module answers "where did it sit when we ran it".
//! `engine::profile` accumulates [`MeasuredCounters`] from whichever
//! backend executed the run — cycle-accurate utilization breakdowns
//! from the simulators, op/byte/sample totals and wall-clock from the
//! software paths — and the pure functions here turn them into a
//! [`MeasuredBoundedness`] verdict plus a [`DriftReport`] against the
//! predicted [`RooflinePoint`](super::RooflinePoint). Everything in
//! this module is arithmetic over already-collected counters: nothing
//! touches an RNG stream, a float reduction order, or a hot loop.

use super::Bottleneck;

/// Agreement band shared with the roofline apex rule: a runner-up
/// busy-fraction within 10% of the leader means no single unit
/// dominates.
const BALANCE_RATIO: f64 = 0.9;

/// Which unit a run was measured to be bound on.
///
/// The first four mirror the roofline's roofs (CU, SU, memory) plus
/// the multi-core crossbar/barrier axis; `Balanced` means no unit's
/// busy share cleared the others by more than the 10% apex band.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MeasuredBoundedness {
    /// Compute units dominated the cycle budget.
    CuBound,
    /// The sampling unit (tree-PU) dominated.
    SuBound,
    /// Memory traffic (busy + bandwidth/bank stalls) dominated.
    MemoryBound,
    /// Cross-core sync barriers + crossbar transfers dominated.
    InterconnectBound,
    /// No single unit dominated (within the 10% band), or no signal.
    Balanced,
}

impl MeasuredBoundedness {
    /// Stable lowercase name used in JSON records and metric labels.
    pub fn name(&self) -> &'static str {
        match self {
            MeasuredBoundedness::CuBound => "cu-bound",
            MeasuredBoundedness::SuBound => "su-bound",
            MeasuredBoundedness::MemoryBound => "memory-bound",
            MeasuredBoundedness::InterconnectBound => "interconnect-bound",
            MeasuredBoundedness::Balanced => "balanced",
        }
    }

    /// Numeric code for the Prometheus boundedness gauge (labels name
    /// the verdict; the value makes it plottable).
    pub fn code(&self) -> f64 {
        match self {
            MeasuredBoundedness::CuBound => 1.0,
            MeasuredBoundedness::SuBound => 2.0,
            MeasuredBoundedness::MemoryBound => 3.0,
            MeasuredBoundedness::InterconnectBound => 4.0,
            MeasuredBoundedness::Balanced => 0.0,
        }
    }

    /// Project an a-priori [`Bottleneck`] verdict onto the measured
    /// vocabulary (the prediction has no interconnect arm; that comes
    /// from [`super::MultiCorePoint::interconnect_bound`]).
    pub fn from_predicted(b: Bottleneck) -> MeasuredBoundedness {
        match b {
            Bottleneck::SamplerBound => MeasuredBoundedness::SuBound,
            Bottleneck::ComputeBound => MeasuredBoundedness::CuBound,
            Bottleneck::MemoryBound => MeasuredBoundedness::MemoryBound,
            Bottleneck::Balanced => MeasuredBoundedness::Balanced,
        }
    }
}

/// Raw measured totals for one run, summed over every chain the
/// backend executed. Software backends fill the op/byte/sample/wall
/// fields; the simulators additionally fill the cycle-domain
/// breakdown (everything from `cycles` down).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MeasuredCounters {
    /// CU ops executed (from per-update `OpCost` accounting).
    pub ops: u64,
    /// Bytes moved (from per-update `OpCost` accounting).
    pub bytes: u64,
    /// Categorical samples drawn.
    pub samples: u64,
    /// RV updates committed.
    pub updates: u64,
    /// Wall-clock seconds (software domain; the sim domain divides
    /// cycles by the modeled clock instead).
    pub wall_seconds: f64,
    /// Total simulated core-cycles (0 on software backends). On
    /// multi-core runs this sums barrier-aligned per-core cycles
    /// (C × makespan) — the denominator for the busy fractions.
    pub cycles: u64,
    /// Simulated seconds on the makespan clock, summed over chains —
    /// the denominator for cycle-domain throughput (0 on software
    /// backends).
    pub sim_seconds: f64,
    /// Cycles with at least one CU lane busy.
    pub cu_busy: u64,
    /// Cycles with the SU tree busy.
    pub su_busy: u64,
    /// Cycles with the memory port busy.
    pub mem_busy: u64,
    /// Cycles stalled on memory bandwidth.
    pub stall_mem_bw: u64,
    /// Cycles stalled on register-file bank conflicts.
    pub stall_bank: u64,
    /// Cycles stalled at cross-core sync barriers.
    pub stall_sync: u64,
    /// Cycles stalled on crossbar contention.
    pub stall_xbar: u64,
    /// Words crossing the shared crossbar.
    pub xfer_words: u64,
}

impl MeasuredCounters {
    /// Whether the cycle-domain breakdown carries any signal.
    pub fn has_cycles(&self) -> bool {
        self.cycles > 0
    }

    /// Measured compute intensity (samples per CU op); `None` when no
    /// op accounting exists (the sims charge ops to the cycle model,
    /// not `OpCost`).
    pub fn measured_ci(&self) -> Option<f64> {
        (self.ops > 0).then(|| self.samples as f64 / self.ops as f64)
    }

    /// Measured memory intensity (samples per byte); `None` without
    /// byte accounting.
    pub fn measured_mi(&self) -> Option<f64> {
        (self.bytes > 0).then(|| self.samples as f64 / self.bytes as f64)
    }
}

/// Classify a run from the busy-fraction of each unit (each in
/// `[0, 1]`, fractions of the total cycle budget).
///
/// The interconnect wins ties at the top — if barriers + crossbar eat
/// as much as the busiest functional unit, adding cores is already
/// not paying. Among CU/SU/memory the leader names the verdict unless
/// the runner-up is within the 10% apex band, which is `Balanced`
/// (the golden configuration of Fig. 6d).
pub fn classify(cu: f64, su: f64, mem: f64, interconnect: f64) -> MeasuredBoundedness {
    let top = cu.max(su).max(mem).max(interconnect);
    let has_signal = top > 0.0;
    if !has_signal {
        return MeasuredBoundedness::Balanced;
    }
    if interconnect >= top {
        return MeasuredBoundedness::InterconnectBound;
    }
    let (leader, runner_up, verdict) = if su >= cu && su >= mem {
        (su, cu.max(mem), MeasuredBoundedness::SuBound)
    } else if cu >= mem {
        (cu, su.max(mem), MeasuredBoundedness::CuBound)
    } else {
        (mem, cu.max(su), MeasuredBoundedness::MemoryBound)
    };
    if runner_up / leader > BALANCE_RATIO {
        MeasuredBoundedness::Balanced
    } else {
        verdict
    }
}

/// [`classify`] over a cycle-domain counter set: memory groups its
/// busy port with bandwidth/bank stalls, interconnect groups sync
/// barriers with crossbar contention.
pub fn classify_cycles(c: &MeasuredCounters) -> MeasuredBoundedness {
    if c.cycles == 0 {
        return MeasuredBoundedness::Balanced;
    }
    let total = c.cycles as f64;
    classify(
        c.cu_busy as f64 / total,
        c.su_busy as f64 / total,
        (c.mem_busy + c.stall_mem_bw + c.stall_bank) as f64 / total,
        (c.stall_sync + c.stall_xbar) as f64 / total,
    )
}

/// Measured-vs-predicted comparison for one run.
#[derive(Clone, Copy, Debug)]
pub struct DriftReport {
    /// The roofline's predicted throughput, GS/s.
    pub predicted_gsps: f64,
    /// What the run delivered, GS/s.
    pub measured_gsps: f64,
    /// Signed drift, percent: `(measured − predicted) / predicted ×
    /// 100`. Negative means the run undershot the roof (expected —
    /// the roofline is an upper bound); positive means the model is
    /// missing something.
    pub drift_pct: f64,
    /// The a-priori bottleneck, projected onto the measured
    /// vocabulary.
    pub predicted: MeasuredBoundedness,
    /// Whether the measured verdict named the same unit.
    pub agree: bool,
}

impl DriftReport {
    /// Compare a measurement against a prediction.
    pub fn new(
        predicted_gsps: f64,
        measured_gsps: f64,
        predicted: MeasuredBoundedness,
        measured: MeasuredBoundedness,
    ) -> DriftReport {
        let drift_pct = if predicted_gsps > 0.0 {
            (measured_gsps - predicted_gsps) / predicted_gsps * 100.0
        } else {
            f64::NAN
        };
        DriftReport {
            predicted_gsps,
            measured_gsps,
            drift_pct,
            predicted,
            agree: predicted == measured,
        }
    }
}

/// One run projected onto the measured roofline: identity, measured
/// axes, verdict, and the drift against the a-priori prediction.
///
/// Serialized as one *flat* JSON object (the server protocol's
/// flat-object parser must be able to read it back), collected into
/// `PROFILE_roofline.json` by `mc2a profile`.
#[derive(Clone, Debug)]
pub struct RooflineObservation {
    /// Registry workload name.
    pub workload: String,
    /// Backend short name (`sw` / `batched` / `sim` / `multicore` /
    /// `runtime`).
    pub backend: String,
    /// Algorithm short name.
    pub algo: String,
    /// Sampler short name.
    pub sampler: String,
    /// Chains in the run.
    pub chains: usize,
    /// Steps per chain.
    pub steps: usize,
    /// Cores (1 except on the multicore backend).
    pub cores: usize,
    /// Total categorical samples drawn across chains.
    pub samples: u64,
    /// Total RV updates committed.
    pub updates: u64,
    /// Wall-clock seconds for the run (host time even for sims).
    pub wall_seconds: f64,
    /// Measured throughput, GS/s. Cycle-domain (deterministic) when
    /// `cycle_domain`, wall-clock otherwise.
    pub measured_gsps: f64,
    /// Measured compute intensity, samples/op (`None` without op
    /// accounting).
    pub measured_ci: Option<f64>,
    /// Measured memory intensity, samples/byte.
    pub measured_mi: Option<f64>,
    /// Whether `measured_gsps` comes from simulated cycles (exactly
    /// reproducible) rather than wall-clock.
    pub cycle_domain: bool,
    /// The measured boundedness verdict.
    pub verdict: MeasuredBoundedness,
    /// CU busy fraction of the cycle budget (sim domain only).
    pub cu_util: Option<f64>,
    /// SU busy fraction (sim domain only).
    pub su_util: Option<f64>,
    /// Memory busy + stall fraction (sim domain only).
    pub mem_util: Option<f64>,
    /// Sync + crossbar stall fraction (sim domain only).
    pub interconnect_frac: Option<f64>,
    /// Measured vs predicted.
    pub drift: DriftReport,
    /// `compiler::analysis` MC2A023 cross-check: did static analysis
    /// predict the interconnect to bind? `None` when the check does
    /// not apply (single core / software).
    pub xbar_predicted_bound: Option<bool>,
}

impl RooflineObservation {
    /// Render as one flat JSON object (one line, parseable by the
    /// server protocol's flat-object parser).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(512);
        s.push('{');
        s.push_str(&format!("\"workload\":{}", jstr(&self.workload)));
        s.push_str(&format!(",\"backend\":{}", jstr(&self.backend)));
        s.push_str(&format!(",\"algo\":{}", jstr(&self.algo)));
        s.push_str(&format!(",\"sampler\":{}", jstr(&self.sampler)));
        s.push_str(&format!(",\"chains\":{}", self.chains));
        s.push_str(&format!(",\"steps\":{}", self.steps));
        s.push_str(&format!(",\"cores\":{}", self.cores));
        s.push_str(&format!(",\"samples\":{}", self.samples));
        s.push_str(&format!(",\"updates\":{}", self.updates));
        s.push_str(&format!(",\"wall_seconds\":{}", jnum(self.wall_seconds)));
        s.push_str(&format!(",\"measured_gsps\":{}", jnum(self.measured_gsps)));
        s.push_str(&format!(",\"measured_ci\":{}", jopt(self.measured_ci)));
        s.push_str(&format!(",\"measured_mi\":{}", jopt(self.measured_mi)));
        s.push_str(&format!(",\"cycle_domain\":{}", self.cycle_domain));
        s.push_str(&format!(",\"verdict\":{}", jstr(self.verdict.name())));
        s.push_str(&format!(",\"cu_util\":{}", jopt(self.cu_util)));
        s.push_str(&format!(",\"su_util\":{}", jopt(self.su_util)));
        s.push_str(&format!(",\"mem_util\":{}", jopt(self.mem_util)));
        s.push_str(&format!(
            ",\"interconnect_frac\":{}",
            jopt(self.interconnect_frac)
        ));
        s.push_str(&format!(
            ",\"predicted_gsps\":{}",
            jnum(self.drift.predicted_gsps)
        ));
        s.push_str(&format!(
            ",\"predicted_verdict\":{}",
            jstr(self.drift.predicted.name())
        ));
        s.push_str(&format!(",\"drift_pct\":{}", jnum(self.drift.drift_pct)));
        s.push_str(&format!(",\"drift_agree\":{}", self.drift.agree));
        match self.xbar_predicted_bound {
            Some(b) => s.push_str(&format!(",\"xbar_predicted_bound\":{b}")),
            None => s.push_str(",\"xbar_predicted_bound\":null"),
        }
        s.push('}');
        s
    }

    /// Render a human-readable block for `mc2a run --profile` /
    /// `mc2a profile --format human`.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "profile {} [{}] algo={} sampler={} chains={} steps={} cores={}\n",
            self.workload, self.backend, self.algo, self.sampler, self.chains, self.steps,
            self.cores
        ));
        let domain = if self.cycle_domain { "cycle" } else { "wall" };
        out.push_str(&format!(
            "  measured   {:>12.6} GS/s ({domain} domain, {} samples, {:.3}s wall)\n",
            self.measured_gsps, self.samples, self.wall_seconds
        ));
        out.push_str(&format!(
            "  predicted  {:>12.6} GS/s  drift {:+.1}%\n",
            self.drift.predicted_gsps, self.drift.drift_pct
        ));
        if let (Some(ci), Some(mi)) = (self.measured_ci, self.measured_mi) {
            out.push_str(&format!(
                "  intensity  CI {ci:.5} samples/op   MI {mi:.5} samples/byte\n"
            ));
        }
        if let (Some(cu), Some(su), Some(mem), Some(icc)) =
            (self.cu_util, self.su_util, self.mem_util, self.interconnect_frac)
        {
            out.push_str(&format!(
                "  busy       CU {:.1}%  SU {:.1}%  mem {:.1}%  interconnect {:.1}%\n",
                cu * 100.0,
                su * 100.0,
                mem * 100.0,
                icc * 100.0
            ));
        }
        out.push_str(&format!(
            "  verdict    {} (predicted {}{})",
            self.verdict.name(),
            self.drift.predicted.name(),
            match self.xbar_predicted_bound {
                Some(true) => ", MC2A023: crossbar flagged",
                Some(false) => ", MC2A023: clear",
                None => "",
            }
        ));
        out
    }
}

fn jstr(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

fn jopt(v: Option<f64>) -> String {
    match v {
        Some(v) => jnum(v),
        None => "null".into(),
    }
}

/// Split a `PROFILE_roofline.json` document into its per-observation
/// flat-object substrings (the objects inside the top-level
/// `"profile"` array). String-aware brace scan — observation objects
/// are flat, so depth 2 inside the document is exactly one record.
pub fn extract_observations(json: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut esc = false;
    let mut start = None;
    for (i, c) in json.char_indices() {
        if in_str {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' => {
                depth += 1;
                if depth == 2 {
                    start = Some(i);
                }
            }
            '}' => {
                if depth == 2 {
                    if let Some(s) = start.take() {
                        out.push(json[s..=i].to_string());
                    }
                }
                depth = depth.saturating_sub(1);
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_names_the_dominant_unit() {
        assert_eq!(classify(0.8, 0.2, 0.1, 0.0), MeasuredBoundedness::CuBound);
        assert_eq!(classify(0.2, 0.9, 0.1, 0.05), MeasuredBoundedness::SuBound);
        assert_eq!(
            classify(0.2, 0.1, 0.7, 0.0),
            MeasuredBoundedness::MemoryBound
        );
        assert_eq!(
            classify(0.2, 0.1, 0.1, 0.5),
            MeasuredBoundedness::InterconnectBound
        );
    }

    #[test]
    fn classify_balanced_cases() {
        // No signal at all.
        assert_eq!(classify(0.0, 0.0, 0.0, 0.0), MeasuredBoundedness::Balanced);
        // Runner-up within the 10% band.
        assert_eq!(classify(0.60, 0.58, 0.1, 0.0), MeasuredBoundedness::Balanced);
        // Exact cu/su tie sits inside the band too.
        assert_eq!(classify(0.5, 0.5, 0.1, 0.0), MeasuredBoundedness::Balanced);
        // Just outside the band: the leader wins.
        assert_eq!(classify(0.60, 0.50, 0.1, 0.0), MeasuredBoundedness::CuBound);
    }

    #[test]
    fn interconnect_wins_ties_at_the_top() {
        // Equal to the busiest functional unit → interconnect-bound
        // (the point where adding cores stops paying).
        assert_eq!(
            classify(0.5, 0.3, 0.2, 0.5),
            MeasuredBoundedness::InterconnectBound
        );
        // Strictly below the top, the functional leader wins even if
        // the interconnect is close.
        assert_eq!(
            classify(0.6, 0.3, 0.2, 0.59),
            MeasuredBoundedness::CuBound
        );
    }

    #[test]
    fn classify_cycles_groups_stalls() {
        let mut c = MeasuredCounters {
            cycles: 100,
            cu_busy: 30,
            su_busy: 20,
            mem_busy: 10,
            stall_mem_bw: 20,
            stall_bank: 15,
            ..MeasuredCounters::default()
        };
        // mem group = (10+20+15)/100 = 0.45 beats cu 0.30.
        assert_eq!(classify_cycles(&c), MeasuredBoundedness::MemoryBound);
        c.stall_sync = 30;
        c.stall_xbar = 20;
        // interconnect = 0.50 ≥ 0.45 → interconnect wins the tie zone.
        assert_eq!(classify_cycles(&c), MeasuredBoundedness::InterconnectBound);
        // Zero cycles → no signal.
        assert_eq!(
            classify_cycles(&MeasuredCounters::default()),
            MeasuredBoundedness::Balanced
        );
    }

    #[test]
    fn drift_report_signs_and_agreement() {
        let d = DriftReport::new(
            10.0,
            8.0,
            MeasuredBoundedness::SuBound,
            MeasuredBoundedness::SuBound,
        );
        assert!((d.drift_pct + 20.0).abs() < 1e-9);
        assert!(d.agree);
        let d = DriftReport::new(
            10.0,
            12.5,
            MeasuredBoundedness::SuBound,
            MeasuredBoundedness::CuBound,
        );
        assert!((d.drift_pct - 25.0).abs() < 1e-9);
        assert!(!d.agree);
        assert!(DriftReport::new(
            0.0,
            1.0,
            MeasuredBoundedness::Balanced,
            MeasuredBoundedness::Balanced
        )
        .drift_pct
        .is_nan());
    }

    #[test]
    fn predicted_bottleneck_projection() {
        assert_eq!(
            MeasuredBoundedness::from_predicted(Bottleneck::SamplerBound),
            MeasuredBoundedness::SuBound
        );
        assert_eq!(
            MeasuredBoundedness::from_predicted(Bottleneck::ComputeBound),
            MeasuredBoundedness::CuBound
        );
        assert_eq!(
            MeasuredBoundedness::from_predicted(Bottleneck::MemoryBound),
            MeasuredBoundedness::MemoryBound
        );
        assert_eq!(
            MeasuredBoundedness::from_predicted(Bottleneck::Balanced),
            MeasuredBoundedness::Balanced
        );
    }

    fn sample_observation() -> RooflineObservation {
        RooflineObservation {
            workload: "earthquake".into(),
            backend: "sim".into(),
            algo: "bg".into(),
            sampler: "gumbel".into(),
            chains: 2,
            steps: 40,
            cores: 1,
            samples: 400,
            updates: 400,
            wall_seconds: 0.01,
            measured_gsps: 0.25,
            measured_ci: None,
            measured_mi: Some(0.05),
            cycle_domain: true,
            verdict: MeasuredBoundedness::SuBound,
            cu_util: Some(0.4),
            su_util: Some(0.9),
            mem_util: Some(0.2),
            interconnect_frac: Some(0.0),
            drift: DriftReport::new(
                0.5,
                0.25,
                MeasuredBoundedness::SuBound,
                MeasuredBoundedness::SuBound,
            ),
            xbar_predicted_bound: None,
        }
    }

    #[test]
    fn observation_json_is_flat_and_complete() {
        let j = sample_observation().to_json();
        // Flat: exactly one object, no nesting.
        assert_eq!(j.matches('{').count(), 1, "{j}");
        assert_eq!(j.matches('}').count(), 1);
        for key in [
            "\"workload\":\"earthquake\"",
            "\"backend\":\"sim\"",
            "\"verdict\":\"su-bound\"",
            "\"predicted_verdict\":\"su-bound\"",
            "\"drift_pct\":-50",
            "\"drift_agree\":true",
            "\"measured_ci\":null",
            "\"cycle_domain\":true",
            "\"xbar_predicted_bound\":null",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[test]
    fn extract_observations_splits_the_profile_document() {
        let a = sample_observation().to_json();
        let mut b = sample_observation();
        b.workload = "with \"quotes\" and }brace{".into();
        let b = b.to_json();
        let doc = format!("{{\"schema\":\"x\",\"observations\":[{a},{b}]}}");
        let got = extract_observations(&doc);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], a);
        assert_eq!(got[1], b);
        assert!(extract_observations("{\"observations\":[]}").is_empty());
    }

    #[test]
    fn render_human_names_both_verdicts() {
        let h = sample_observation().render_human();
        assert!(h.contains("su-bound"), "{h}");
        assert!(h.contains("drift -50.0%"), "{h}");
        assert!(h.contains("cycle domain"), "{h}");
    }
}
