//! The MC²A 3D roofline model (§IV, Fig. 6) and the design-space
//! exploration built on it (§VI-B, Fig. 11).
//!
//! The model adds a third axis to the classic roofline: alongside
//! **Compute Intensity** (samples per CU op) and **Memory Intensity**
//! (samples per byte), the vertical axis is **Throughput Performance**
//! in Giga-samples/s. Three roofs bound the achievable envelope — the
//! SU peak sampling rate, the CU peak scaled by CI, and the memory
//! bandwidth scaled by MI — forming the rectangular-frustum shape of
//! Fig. 6(a). A workload pins a (CI, MI) point; the envelope height at
//! that point is the predicted throughput, and which roof is lowest
//! names the bottleneck.

pub mod dse;
pub mod observe;

pub use dse::{area_units, dse_sweep, DseCandidate, DseResult};
pub use observe::{DriftReport, MeasuredBoundedness, MeasuredCounters, RooflineObservation};

use crate::energy::EnergyModel;
use crate::isa::{HwConfig, MultiHwConfig};
use crate::mcmc::AlgoKind;

/// A workload's position in the roofline plane plus the SU shape it
/// needs (distribution size and mode decide the effective SU roof).
#[derive(Clone, Copy, Debug)]
pub struct WorkloadProfile {
    /// Samples per CU op (CI), in samples/op.
    pub ci: f64,
    /// Samples per byte of memory traffic (MI), in samples/byte.
    pub mi: f64,
    /// Mean categorical distribution size per sample.
    pub dist_size: f64,
    /// Whether the schedule uses the spatial-mode SU (PAS) or temporal.
    pub spatial: bool,
}

impl WorkloadProfile {
    /// Profile a *(model, algorithm)* pair by aggregating the per-RV
    /// update costs (§II-C's three steps).
    pub fn from_model(model: &dyn EnergyModel, algo: AlgoKind) -> WorkloadProfile {
        let n = model.num_vars();
        let mut ops = 0u64;
        let mut bytes = 0u64;
        let mut samples = 0u64;
        let mut dist = 0f64;
        for i in 0..n {
            let c = model.update_cost(i);
            ops += c.ops;
            bytes += c.bytes;
            samples += c.samples;
            dist += model.num_states(i) as f64;
        }
        let spatial = matches!(algo, AlgoKind::Pas);
        let dist_size = if spatial {
            // PAS samples indices from the full move table.
            dist
        } else {
            dist / n as f64
        };
        WorkloadProfile {
            ci: samples as f64 / ops.max(1) as f64,
            mi: samples as f64 / bytes.max(1) as f64,
            dist_size,
            spatial,
        }
    }

    /// The Fig. 6(c) Ising example: 4 neighbor reads (16 B) + state
    /// write, ~10 ops, 1 sample from a size-2 distribution.
    pub fn fig6_ising_example() -> WorkloadProfile {
        WorkloadProfile {
            ci: 1.0 / 10.0,
            mi: 1.0 / 20.0,
            dist_size: 2.0,
            spatial: false,
        }
    }
}

/// Which roof limits the workload (Fig. 6(d) verdicts).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bottleneck {
    /// Sample-unit bound: CU and memory can feed more than the SU eats.
    SamplerBound,
    /// Compute bound (the CU-performance corner zone).
    ComputeBound,
    /// Memory-bandwidth bound (the gray zone of Fig. 11).
    MemoryBound,
    /// Within 10% of the apex — the golden balanced configuration.
    Balanced,
}

/// Roofline evaluation of one workload on one hardware config.
#[derive(Clone, Copy, Debug)]
pub struct RooflinePoint {
    /// Predicted throughput in GS/s.
    pub tp_gsps: f64,
    /// SU roof at this workload's distribution shape, GS/s.
    pub su_roof: f64,
    /// CU roof (CI × peak ops/s), GS/s.
    pub cu_roof: f64,
    /// Memory roof (MI × peak bytes/s), GS/s.
    pub mem_roof: f64,
    /// The binding constraint.
    pub bottleneck: Bottleneck,
}

/// Effective SU peak sampling rate for a distribution shape, GS/s.
///
/// Temporal mode: S SEs each retire one size-N sample every N cycles →
/// `S / N` samples/cycle. Spatial mode: the SE tree retires one sample
/// every `ceil(N/S)` cycles → `1 / ceil(N/S)` samples/cycle.
pub fn su_roof_gsps(hw: &HwConfig, dist_size: f64, spatial: bool) -> f64 {
    let n = dist_size.max(1.0);
    let samples_per_cycle = if spatial {
        1.0 / (n / hw.s as f64).ceil()
    } else {
        hw.s as f64 / n
    };
    samples_per_cycle * hw.clock_ghz
}

/// Evaluate the 3D roofline at a workload point.
pub fn evaluate(hw: &HwConfig, w: &WorkloadProfile) -> RooflinePoint {
    let su_roof = su_roof_gsps(hw, w.dist_size, w.spatial);
    let cu_roof = w.ci * hw.cu_peak_ops_per_cycle() as f64 * hw.clock_ghz;
    let mem_roof = w.mi * hw.mem_peak_bytes_per_cycle() as f64 * hw.clock_ghz;
    let tp = su_roof.min(cu_roof).min(mem_roof);
    let bottleneck = if (su_roof.min(cu_roof).min(mem_roof) / su_roof.max(cu_roof).max(mem_roof))
        > 0.9
    {
        Bottleneck::Balanced
    } else if tp == su_roof && su_roof < cu_roof && su_roof < mem_roof {
        Bottleneck::SamplerBound
    } else if tp == cu_roof && cu_roof <= mem_roof {
        Bottleneck::ComputeBound
    } else {
        Bottleneck::MemoryBound
    };
    RooflinePoint {
        tp_gsps: tp,
        su_roof,
        cu_roof,
        mem_roof,
        bottleneck,
    }
}

/// The C-core operating point plotted against the single-core one
/// (§II-D scaling): each core is bounded by the single-core envelope,
/// and the aggregate is additionally capped by the shared
/// crossbar/histogram port every sample must cross.
#[derive(Clone, Copy, Debug)]
pub struct MultiCorePoint {
    /// The single-core evaluation (the reference point).
    pub single: RooflinePoint,
    /// Core count C.
    pub cores: usize,
    /// Ideal linear scaling: C × single-core TP, GS/s.
    pub linear_tp: f64,
    /// Shared-interconnect roof, GS/s (∞ at C = 1 — a single core
    /// owns its ports).
    pub xbar_roof: f64,
    /// Predicted aggregate throughput: min(linear, crossbar), GS/s.
    pub tp_gsps: f64,
    /// True when the shared interconnect (not the per-core envelope)
    /// binds — the point where adding cores stops paying.
    pub interconnect_bound: bool,
}

/// Evaluate the C-core roofline at a workload point.
///
/// `boundary_fraction` is the fraction of samples whose RV sits on a
/// shard boundary (obtain it from
/// [`crate::graph::Partition::boundary_fraction`]); each such sample
/// broadcasts one word, and every sample commits one shared-histogram
/// word, so the port moves `boundary_fraction + 1` words per sample.
pub fn evaluate_multicore(
    mhw: &MultiHwConfig,
    w: &WorkloadProfile,
    boundary_fraction: f64,
) -> MultiCorePoint {
    let single = evaluate(&mhw.core, w);
    let linear_tp = single.tp_gsps * mhw.cores as f64;
    if mhw.cores <= 1 {
        return MultiCorePoint {
            single,
            cores: mhw.cores,
            linear_tp,
            xbar_roof: f64::INFINITY,
            tp_gsps: single.tp_gsps,
            interconnect_bound: false,
        };
    }
    let words_per_sample = boundary_fraction.max(0.0) + 1.0;
    let xbar_roof = mhw.xbar_words_per_cycle as f64 * mhw.core.clock_ghz / words_per_sample;
    MultiCorePoint {
        single,
        cores: mhw.cores,
        linear_tp,
        xbar_roof,
        tp_gsps: linear_tp.min(xbar_roof),
        interconnect_bound: xbar_roof < linear_tp,
    }
}

/// The roofline apex (the purple star of Fig. 6a): the (CI*, MI*) where
/// the three roofs intersect — the workload shape this hardware serves
/// with every unit saturated.
pub fn apex(hw: &HwConfig, dist_size: f64, spatial: bool) -> (f64, f64) {
    let su = su_roof_gsps(hw, dist_size, spatial);
    let ci_star = su / (hw.cu_peak_ops_per_cycle() as f64 * hw.clock_ghz);
    let mi_star = su / (hw.mem_peak_bytes_per_cycle() as f64 * hw.clock_ghz);
    (ci_star, mi_star)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::PottsGrid;

    #[test]
    fn su_roof_shapes() {
        let hw = HwConfig::paper_default(); // S = 64, 0.5 GHz
        // Temporal, size-2: 64/2 = 32 samples/cycle → 16 GS/s.
        assert!((su_roof_gsps(&hw, 2.0, false) - 16.0).abs() < 1e-9);
        // Spatial, size-256: ceil(256/64) = 4 cycles → 0.125 GS/s.
        assert!((su_roof_gsps(&hw, 256.0, true) - 0.125).abs() < 1e-9);
    }

    #[test]
    fn fig6_example_on_balanced_hw() {
        // Fig. 6(d): config CU=10 OP/cy, BW=20 B/cy, SU=1 S/cy is the
        // golden match for the Ising example (CI=0.1, MI=0.05).
        let hw = HwConfig {
            t: 1,
            k: 3,
            s: 2,
            m: 1,
            bw_words: 5,
            clock_ghz: 0.5,
            rf_banks: 4,
            rf_regs_per_bank: 16,
            lut_size: 16,
            lut_bits: 8,
            max_dist_size: 256,
        };
        // CU peak = 1×(8+2) = 10 ops/cycle; mem = 20 B/cycle; SU
        // temporal size-2 = 2/2 = 1 sample/cycle. All three roofs equal
        // 0.5 GS/s → balanced apex.
        let w = WorkloadProfile::fig6_ising_example();
        let p = evaluate(&hw, &w);
        assert!((p.su_roof - 0.5).abs() < 1e-9, "{p:?}");
        assert!((p.cu_roof - 0.5).abs() < 1e-9);
        assert!((p.mem_roof - 0.5).abs() < 1e-9);
        assert_eq!(p.bottleneck, Bottleneck::Balanced);
    }

    #[test]
    fn scaling_cu_down_makes_compute_bound() {
        let mut hw = HwConfig::paper_default();
        hw.t = 1;
        hw.k = 0; // CU peak = 3 ops/cycle
        let w = WorkloadProfile::fig6_ising_example();
        let p = evaluate(&hw, &w);
        assert_eq!(p.bottleneck, Bottleneck::ComputeBound);
        assert!(p.tp_gsps < p.su_roof);
    }

    #[test]
    fn scaling_bw_down_makes_memory_bound() {
        let mut hw = HwConfig::paper_default();
        hw.bw_words = 1;
        let w = WorkloadProfile::fig6_ising_example();
        let p = evaluate(&hw, &w);
        assert_eq!(p.bottleneck, Bottleneck::MemoryBound);
    }

    #[test]
    fn apex_matches_roof_intersection() {
        let hw = HwConfig::paper_default();
        let (ci, mi) = apex(&hw, 2.0, false);
        let w = WorkloadProfile {
            ci,
            mi,
            dist_size: 2.0,
            spatial: false,
        };
        let p = evaluate(&hw, &w);
        assert_eq!(p.bottleneck, Bottleneck::Balanced);
        assert!((p.cu_roof - p.su_roof).abs() / p.su_roof < 1e-9);
        assert!((p.mem_roof - p.su_roof).abs() / p.su_roof < 1e-9);
    }

    #[test]
    fn multicore_roofline_scales_until_the_crossbar_binds() {
        use crate::isa::MultiHwConfig;
        let w = WorkloadProfile::fig6_ising_example();
        let hw = HwConfig::paper_default();
        let one = evaluate_multicore(&MultiHwConfig::new(hw, 1), &w, 0.2);
        assert_eq!(one.tp_gsps, one.single.tp_gsps);
        assert!(!one.interconnect_bound);

        let four = evaluate_multicore(&MultiHwConfig::new(hw, 4), &w, 0.2);
        assert!(four.tp_gsps > one.tp_gsps);
        assert!(four.tp_gsps <= four.linear_tp);

        // Saturate the shared port: heavy boundary traffic on many
        // cores must become interconnect-bound below linear scaling.
        let mut mhw = MultiHwConfig::new(hw, 64);
        mhw.xbar_words_per_cycle = 8;
        let congested = evaluate_multicore(&mhw, &w, 1.0);
        assert!(congested.interconnect_bound);
        assert!(congested.tp_gsps < congested.linear_tp);
        assert!((congested.tp_gsps - congested.xbar_roof).abs() < 1e-12);
    }

    #[test]
    fn profile_from_model_sane() {
        let m = PottsGrid::new(8, 8, 2, 1.0);
        let w = WorkloadProfile::from_model(&m, AlgoKind::BlockGibbs);
        assert!(w.ci > 0.0 && w.ci < 1.0); // several ops per sample
        assert!(w.mi > 0.0 && w.mi < 1.0); // several bytes per sample
        assert_eq!(w.dist_size, 2.0);
        assert!(!w.spatial);
        let wp = WorkloadProfile::from_model(&m, AlgoKind::Pas);
        assert!(wp.spatial);
        assert!(wp.dist_size > 100.0); // full move table
    }
}
