//! MC²A command-line interface.
//!
//! ```text
//! mc2a table1 [--full]
//! mc2a bench <fig5|fig6|fig11|fig12|fig13|fig14|fig15|headline|all> [--full]
//! mc2a run --workload <name> [--algo mh|gibbs|bg|ag|pas] [--steps N]
//!          [--chains N] [--backend sim|sw] [--beta B]
//! mc2a roofline [--workload <name>]
//! mc2a dse
//! mc2a runtime-check [--artifacts DIR]
//! ```
//!
//! (Hand-rolled argument parsing: the offline vendor set has no clap.)

use mc2a::bench;
use mc2a::coordinator::{run_chains, Backend, RunSpec};
use mc2a::isa::HwConfig;
use mc2a::mcmc::{AlgoKind, BetaSchedule, SamplerKind};
use mc2a::roofline::{self, WorkloadProfile};
use mc2a::runtime::Runtime;
use mc2a::workloads::{self, Workload};

fn usage() -> ! {
    eprintln!(
        "mc2a — MC²A algorithm-hardware co-design framework (paper reproduction)

USAGE:
  mc2a table1 [--full]
  mc2a bench <fig5|fig6|fig11|fig12|fig13|fig14|fig15|headline|all> [--full]
  mc2a run --workload <name> [--algo mh|gibbs|bg|ag|pas] [--steps N]
           [--chains N] [--backend sim|sw] [--beta B] [--seed S]
  mc2a roofline [--workload <name>]
  mc2a dse
  mc2a runtime-check [--artifacts DIR]

Workloads: earthquake survey cancer alarm imageseg imageseg-full er700
           twitter optsicom rbm"
    );
    std::process::exit(2);
}

/// Fetch the value following a `--flag`.
fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

fn find_workload(name: &str) -> Option<Workload> {
    match name.to_ascii_lowercase().as_str() {
        "earthquake" => Some(workloads::wl_earthquake()),
        "survey" => Some(workloads::wl_survey()),
        "cancer" => Some(workloads::wl_cancer()),
        "alarm" => Some(workloads::wl_alarm()),
        "imageseg" => Some(workloads::wl_image_seg(false)),
        "imageseg-full" => Some(workloads::wl_image_seg(true)),
        "er700" | "mis" => Some(workloads::wl_mis_er()),
        "twitter" | "maxclique" => Some(workloads::wl_maxclique_twitter()),
        "optsicom" | "maxcut" => Some(workloads::wl_maxcut_optsicom()),
        "rbm" => Some(workloads::wl_rbm()),
        _ => None,
    }
}

fn cmd_bench(args: &[String]) {
    let which = args.first().map(String::as_str).unwrap_or("all");
    let full = has_flag(args, "--full");
    let quick = !full;
    let run = |name: &str| match name {
        "fig5" => bench::fig5(quick, 0.94),
        "fig6" => bench::fig6(),
        "fig11" => bench::fig11(),
        "fig12" => bench::fig12(quick),
        "fig13" => bench::fig13(),
        "fig14" => bench::fig14(quick),
        "fig15" => bench::fig15(quick),
        "headline" => bench::headline(quick),
        other => {
            eprintln!("unknown figure {other}");
            std::process::exit(2);
        }
    };
    if which == "all" {
        for f in [
            "fig5", "fig6", "fig11", "fig12", "fig13", "fig14", "fig15", "headline",
        ] {
            println!("{}", run(f));
        }
    } else {
        println!("{}", run(which));
    }
}

fn cmd_run(args: &[String]) {
    let Some(wname) = flag_value(args, "--workload") else {
        usage()
    };
    let Some(wl) = find_workload(&wname) else {
        eprintln!("unknown workload {wname}");
        std::process::exit(2);
    };
    let algo = flag_value(args, "--algo")
        .map(|a| AlgoKind::parse(&a).unwrap_or_else(|| usage()))
        .unwrap_or(wl.algorithm);
    let steps: usize = flag_value(args, "--steps")
        .map(|s| s.parse().unwrap_or_else(|_| usage()))
        .unwrap_or(200);
    let chains: usize = flag_value(args, "--chains")
        .map(|s| s.parse().unwrap_or_else(|_| usage()))
        .unwrap_or(1);
    let beta: f32 = flag_value(args, "--beta")
        .map(|s| s.parse().unwrap_or_else(|_| usage()))
        .unwrap_or(1.0);
    let seed: u64 = flag_value(args, "--seed")
        .map(|s| s.parse().unwrap_or_else(|_| usage()))
        .unwrap_or(1);
    let backend = match flag_value(args, "--backend").as_deref() {
        Some("sim") => Backend::Accelerator(HwConfig::paper_default()),
        _ => Backend::Software(SamplerKind::Gumbel),
    };
    let spec = RunSpec {
        algo,
        schedule: BetaSchedule::Constant(beta),
        steps,
        chains,
        seed,
        pas_flips: wl.pas_flips,
    };
    println!(
        "workload={} nodes={} edges={} algo={} steps={steps} chains={chains}",
        wl.name,
        wl.nodes(),
        wl.edges(),
        algo.name()
    );
    let metrics = run_chains(wl.model.as_ref(), backend, spec);
    for c in &metrics.chains {
        print!(
            "chain {}: best objective {:.2}, {} updates, {:?}",
            c.chain_id, c.best_objective, c.stats.updates, c.wall
        );
        if let Some(rep) = &c.sim {
            print!(
                ", {} cycles, {:.4} GS/s, {:.2} W (modeled)",
                rep.cycles,
                rep.gsps(&HwConfig::paper_default()),
                rep.watts(&HwConfig::paper_default()),
            );
        }
        println!();
    }
    println!(
        "best objective overall: {:.2}; software wall throughput {:.3e} updates/s",
        metrics.best_objective(),
        metrics.updates_per_sec()
    );
}

fn cmd_roofline(args: &[String]) {
    if let Some(wname) = flag_value(args, "--workload") {
        let Some(wl) = find_workload(&wname) else {
            eprintln!("unknown workload {wname}");
            std::process::exit(2);
        };
        let hw = HwConfig::paper_default();
        let p = WorkloadProfile::from_model(wl.model.as_ref(), wl.algorithm);
        let r = roofline::evaluate(&hw, &p);
        println!(
            "workload={} CI={:.5} MI={:.5} dist={:.0} mode={}",
            wl.name,
            p.ci,
            p.mi,
            p.dist_size,
            if p.spatial { "spatial" } else { "temporal" }
        );
        println!(
            "TP={:.4} GS/s (SU {:.4} / CU {:.4} / MEM {:.4}) bottleneck={:?}",
            r.tp_gsps, r.su_roof, r.cu_roof, r.mem_roof, r.bottleneck
        );
    } else {
        println!("{}", bench::fig6());
    }
}

fn cmd_runtime_check(args: &[String]) {
    let dir = flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    match Runtime::load(&dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            println!("artifacts: {:?}", rt.names());
            print!("{}", bench::measured_cpu_rows(&rt));
        }
        Err(e) => {
            eprintln!("runtime check failed: {e:#}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("table1") => println!("{}", bench::table1(has_flag(&args[1..], "--full"))),
        Some("bench") => cmd_bench(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("roofline") => cmd_roofline(&args[1..]),
        Some("dse") => println!("{}", bench::fig11()),
        Some("runtime-check") => cmd_runtime_check(&args[1..]),
        _ => usage(),
    }
}
