//! MC²A command-line interface.
//!
//! ```text
//! mc2a table1 [--full]
//! mc2a bench <fig5|fig6|fig11|fig12|fig13|fig14|fig15|chains|cores|anneal|temper|headline|all> [--full]
//! mc2a run --workload <name> [--algo mh|gibbs|bg|ag|pas]
//!          [--sampler cdf|gumbel|lut|lut:SIZE:BITS] [--steps N] [--chains N]
//!          [--backend sim|sw|batched|multicore|runtime]
//!          [--batch K] [--threads T] [--cores C]
//!          [--beta B | --schedule const:B|linear:FROM:TO:STEPS|geom:FROM:TO:RATE]
//!          [--adaptive reheat|plateau]
//!          [--temper K] [--swap-every N] [--ladder geom:FROM:TO|explicit:B1,B2,…]
//!          [--swap-target RATE] [--seed S] [--observe N]
//!          [--save-state PATH] [--init-from PATH] [--trace OUT.json] [--profile]
//! mc2a serve [--addr HOST:PORT] [--dir JOBDIR] [--threads N] [--recover]
//!            [--metrics-addr HOST:PORT] [--trace OUT.json]
//! mc2a client [--addr HOST:PORT]
//!             <submit|status|result|cancel|stream|metrics|stats|shutdown|ping> …
//! mc2a check (--workload <name> | --all) [--algo mh|gibbs|bg|ag|pas]
//!            [--sampler cdf|gumbel|lut|lut:SIZE:BITS] [--cores C]
//!            [--hw paper|toy|t=..,k=..,…] [--format human|json] [--heavy]
//! mc2a profile (--workload <name> | --all) [--backends sw,batched,sim,multicore]
//!              [--steps N] [--chains N] [--seed S] [--cores C]
//!              [--format human|json] [--max-drift PCT]
//! mc2a workloads
//! mc2a roofline [--workload <name>] [--cores C] [--format human|json]
//!               [--observed PROFILE_roofline.json]
//! mc2a dse
//! mc2a runtime-check [--artifacts DIR]
//! ```
//!
//! (Hand-rolled argument parsing: the offline vendor set has no clap.)
//!
//! All run construction goes through [`mc2a::engine::EngineBuilder`];
//! this file is the only place allowed to call `process::exit`.

use std::path::PathBuf;
use std::time::Duration;

use mc2a::bench;
use mc2a::engine::server::{net, proto};
use mc2a::engine::telemetry;
use mc2a::engine::{
    registry, Checkpoint, Engine, JobServer, JobServerConfig, JobSpec, Mc2aError, PrintObserver,
    Priority, ServeBackend,
};
use mc2a::isa::{HwConfig, MultiHwConfig};
use mc2a::mcmc::{AlgoKind, AnnealPolicy, BetaSchedule, Ladder, SamplerKind};
use mc2a::rng::Rng;
use mc2a::roofline::{self, WorkloadProfile};
use mc2a::runtime::Runtime;

fn usage() -> ! {
    eprintln!(
        "mc2a — MC²A algorithm-hardware co-design framework (paper reproduction)

USAGE:
  mc2a table1 [--full]
  mc2a bench <fig5|fig6|fig11|fig12|fig13|fig14|fig15|chains|cores|anneal|temper|headline|all> [--full]
  mc2a run --workload <name> [--algo mh|gibbs|bg|ag|pas]
           [--sampler cdf|gumbel|lut|lut:SIZE:BITS] [--steps N] [--chains N]
           [--backend sim|sw|batched|multicore|runtime]
           [--batch K] [--threads T] [--cores C]
           [--beta B | --schedule const:B|linear:FROM:TO:STEPS|geom:FROM:TO:RATE]
           [--adaptive reheat|plateau]
           [--temper K] [--swap-every N] [--ladder geom:FROM:TO|explicit:B1,B2,…]
           [--swap-target RATE] [--seed S] [--observe N]
           [--save-state PATH] [--init-from PATH] [--trace OUT.json] [--profile]
  mc2a serve [--addr HOST:PORT] [--dir JOBDIR] [--threads N]
             [--recover] [--force-backend sw|sim]
             [--metrics-addr HOST:PORT] [--trace OUT.json]
  mc2a client [--addr HOST:PORT] [--connect-retries N]
              <submit|status|result|cancel|stream|metrics|stats|shutdown|ping>
              submit: --workload <name> [--steps N] [--chains N] [--seed S]
                      [--beta B] [--algo A] [--sampler S] [--observe N]
                      [--backend sw|sim] [--priority low|normal|high] [--trace]
                      [--profile]
              status [--job N] | cancel/stream --job N
              result --job N [--wait] [--timeout SECS]
  mc2a check (--workload <name> | --all) [--algo mh|gibbs|bg|ag|pas]
             [--sampler cdf|gumbel|lut|lut:SIZE:BITS] [--cores C]
             [--hw paper|toy|t=..,k=..,s=..,m=..,b=..,banks=..,regs=..,lut=..,lutbits=..,maxdist=..]
             [--format human|json] [--heavy]
  mc2a profile (--workload <name> | --all) [--backends sw,batched,sim,multicore]
               [--steps N] [--chains N] [--seed S] [--cores C]
               [--format human|json] [--max-drift PCT]
  mc2a workloads
  mc2a roofline [--workload <name>] [--cores C] [--format human|json]
                [--observed PROFILE_roofline.json]
  mc2a dse
  mc2a runtime-check [--artifacts DIR]

Run `mc2a workloads` for the registered workload list."
    );
    std::process::exit(2);
}

/// Fetch the value following a `--flag`.
fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

/// Parse the value of `--flag` with a typed error instead of a usage dump.
fn parsed_flag<T: std::str::FromStr>(args: &[String], flag: &str) -> Result<Option<T>, Mc2aError> {
    match flag_value(args, flag) {
        None => Ok(None),
        Some(raw) => raw.parse::<T>().map(Some).map_err(|_| {
            Mc2aError::InvalidConfig(format!("bad value {raw:?} for {flag}"))
        }),
    }
}

fn cmd_bench(args: &[String]) -> Result<(), Mc2aError> {
    let which = args.first().map(String::as_str).unwrap_or("all");
    let full = has_flag(args, "--full");
    let quick = !full;
    let run = |name: &str| -> Result<String, Mc2aError> {
        Ok(match name {
            "fig5" => bench::fig5(quick, 0.94),
            "fig6" => bench::fig6(),
            "fig11" => bench::fig11(),
            "fig12" => bench::fig12(quick),
            "fig13" => bench::fig13(),
            "fig14" => bench::fig14(quick),
            "fig15" => bench::fig15(quick),
            "chains" => bench::many_chains(quick)?,
            "serve" => bench::serve_throughput(quick)?,
            "cores" => bench::core_scaling(quick)?,
            "anneal" => bench::anneal_compare(quick)?,
            "temper" => bench::temper_compare(quick)?,
            "headline" => bench::headline(quick),
            other => {
                let mut known: Vec<String> =
                    bench::BENCH_NAMES.iter().map(|s| s.to_string()).collect();
                known.push("all".into());
                return Err(Mc2aError::UnknownBench {
                    name: other.to_string(),
                    known,
                });
            }
        })
    };
    if which == "all" {
        for f in bench::BENCH_NAMES {
            println!("{}", run(f)?);
        }
    } else {
        println!("{}", run(which)?);
    }
    Ok(())
}

/// Parse a `--schedule` spec: `const:B`, `linear:FROM:TO:STEPS` or
/// `geom:FROM:TO:RATE` (the builder validates the numbers).
fn parse_schedule(s: &str) -> Result<BetaSchedule, Mc2aError> {
    fn bad(s: &str) -> Mc2aError {
        Mc2aError::InvalidConfig(format!(
            "bad schedule {s:?} (const:B | linear:FROM:TO:STEPS | geom:FROM:TO:RATE)"
        ))
    }
    let num = |tok: &str| -> Result<f32, Mc2aError> { tok.parse::<f32>().map_err(|_| bad(s)) };
    let parts: Vec<&str> = s.split(':').collect();
    match parts.as_slice() {
        ["const", b] => Ok(BetaSchedule::Constant(num(b)?)),
        ["linear", f, t, n] => Ok(BetaSchedule::Linear {
            from: num(f)?,
            to: num(t)?,
            steps: n.parse::<usize>().map_err(|_| bad(s))?,
        }),
        ["geom", f, t, r] | ["geometric", f, t, r] => Ok(BetaSchedule::Geometric {
            from: num(f)?,
            to: num(t)?,
            rate: num(r)?,
        }),
        _ => Err(bad(s)),
    }
}

fn cmd_run(args: &[String]) -> Result<(), Mc2aError> {
    let wname = flag_value(args, "--workload")
        .ok_or_else(|| Mc2aError::InvalidConfig("--workload is required".into()))?;
    let mut builder = Engine::for_workload(&wname)?;
    if let Some(a) = flag_value(args, "--algo") {
        let algo = AlgoKind::parse(&a).ok_or_else(|| {
            Mc2aError::InvalidConfig(format!("unknown algo {a:?} (mh|gibbs|bg|ag|pas)"))
        })?;
        builder = builder.algo(algo);
    }
    if let Some(s) = flag_value(args, "--sampler") {
        let sampler = SamplerKind::parse(&s)
            .map_err(|e| Mc2aError::InvalidConfig(e.to_string()))?;
        builder = builder.sampler(sampler);
    }
    let steps: usize = parsed_flag(args, "--steps")?.unwrap_or(200);
    let chains: usize = parsed_flag(args, "--chains")?.unwrap_or(1);
    let seed_flag: Option<u64> = parsed_flag(args, "--seed")?;
    let schedule_flags = (flag_value(args, "--schedule"), parsed_flag::<f32>(args, "--beta")?);
    if has_flag(args, "--temper") && (schedule_flags.0.is_some() || schedule_flags.1.is_some()) {
        return Err(Mc2aError::InvalidConfig(
            "--temper fixes each replica's β from the ladder; drop --beta/--schedule \
             (use --ladder to choose the temperatures)"
                .into(),
        ));
    }
    let schedule = match schedule_flags {
        (Some(_), Some(_)) => {
            return Err(Mc2aError::InvalidConfig(
                "--beta is shorthand for --schedule const:B; pass one or the other".into(),
            ))
        }
        (Some(spec), None) => parse_schedule(&spec)?,
        (None, Some(b)) => BetaSchedule::Constant(b),
        (None, None) => BetaSchedule::Constant(1.0),
    };
    let adaptive: Option<AnnealPolicy> = match flag_value(args, "--adaptive") {
        Some(p) => Some(AnnealPolicy::parse(&p).ok_or_else(|| {
            Mc2aError::InvalidConfig(format!("unknown adaptive policy {p:?} (reheat|plateau)"))
        })?),
        None => None,
    };
    let temper: Option<usize> = parsed_flag(args, "--temper")?;
    let swap_every: Option<usize> = parsed_flag(args, "--swap-every")?;
    let swap_target: Option<f64> = parsed_flag(args, "--swap-target")?;
    let ladder_spec = flag_value(args, "--ladder");
    if temper.is_none() && (swap_every.is_some() || swap_target.is_some() || ladder_spec.is_some())
    {
        return Err(Mc2aError::InvalidConfig(
            "--swap-every/--swap-target/--ladder require --temper K".into(),
        ));
    }
    let ladder = match temper {
        // `--temper 1` (or 0) falls through to Ladder::validate's
        // "needs at least 2 rungs" typed error via parse.
        Some(k) => {
            if adaptive.is_some() {
                return Err(Mc2aError::InvalidConfig(
                    "--temper and --adaptive are mutually exclusive (each replica's β \
                     is fixed by its ladder rung)"
                        .into(),
                ));
            }
            let spec = ladder_spec.as_deref().unwrap_or("geom:0.2:4.0");
            Some(Ladder::parse(spec, k).map_err(Mc2aError::InvalidConfig)?)
        }
        None => None,
    };
    // Steps completed before this invocation (from `--init-from`), so a
    // later `--save-state` records cumulative progress across resumes
    // and the β ramp continues at the checkpoint's step count.
    let mut prior_steps = 0usize;
    // Without an explicit --seed, a resumed run continues on a seed
    // derived from (checkpoint seed, checkpoint steps) — replaying the
    // original RNG streams from the best state would just re-explore
    // the same trajectories.
    let mut resume_seed: Option<u64> = None;
    // Shape flags are applied *before* `--init-from` so the checkpoint
    // is validated against this run's final workload/sampler/chain
    // configuration, not the defaults.
    builder = builder.steps(steps).chains(chains).schedule(schedule);
    if let Some(path) = flag_value(args, "--init-from") {
        let ck = Checkpoint::load(&path)?;
        prior_steps = ck.steps;
        resume_seed = Some(Rng::fork_seed(ck.seed, ck.steps as u64 + 1));
        println!(
            "resuming from {path}: {} steps done, best objective {:.2}",
            ck.steps, ck.best_objective
        );
        builder = builder.init_from_checkpoint(&ck)?;
        // Adaptive resume also restores the controller's memory, so
        // plateau counters and the virtual clock carry over.
        if adaptive.is_some() {
            if let Some(state) = ck.anneal {
                builder = builder.anneal_state(state);
            }
        }
        // Tempered resume continues the ladder, the chain→rung
        // assignment and the swap schedule. Note: a resumed run with a
        // fresh seed re-forks the *chain* streams, but the swap stream
        // position is part of the serialized state.
        if temper.is_some() {
            if let Some(state) = ck.temper {
                builder = builder.temper_state(state);
            }
        }
    }
    let seed: u64 = seed_flag.or(resume_seed).unwrap_or(1);
    builder = builder.seed(seed);
    if let Some(policy) = adaptive {
        builder = builder.adaptive(policy);
    }
    if let Some(l) = ladder {
        builder = builder.tempering(l);
        if let Some(every) = swap_every {
            builder = builder.swap_every(every);
        }
        if let Some(rate) = swap_target {
            builder = builder.temper_adapt(rate);
        }
    }
    let hw = HwConfig::paper_default();
    let batch: Option<usize> = parsed_flag(args, "--batch")?;
    let threads: Option<usize> = parsed_flag(args, "--threads")?;
    let cores: Option<usize> = parsed_flag(args, "--cores")?;
    builder = match flag_value(args, "--backend").as_deref() {
        Some("sim") => builder.accelerator(hw),
        Some("multicore") => builder.multicore(hw),
        Some("runtime") => {
            builder.runtime(flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into()))
        }
        Some("batched") => builder.batched(),
        // An *explicit* `sw` with batch knobs is a contradiction, not
        // an auto-switch — same rule build() applies to sim/runtime.
        Some("sw") if batch.is_some() || threads.is_some() => {
            return Err(Mc2aError::InvalidConfig(
                "--batch/--threads require the batched backend (drop --backend sw \
                 or use --backend batched)"
                    .into(),
            ))
        }
        Some("sw") if cores.is_some() => {
            return Err(Mc2aError::InvalidConfig(
                "--cores requires the multi-core backend (drop --backend sw \
                 or use --backend multicore)"
                    .into(),
            ))
        }
        // With no backend flag, `--batch`/`--threads`/`--cores` below
        // switch the default software backend via the builder.
        Some("sw") | None => builder.software(),
        Some(other) => {
            return Err(Mc2aError::InvalidConfig(format!(
                "unknown backend {other:?} (sim|sw|batched|multicore|runtime)"
            )))
        }
    };
    if let Some(k) = batch {
        builder = builder.batch(k);
    }
    if let Some(t) = threads {
        builder = builder.threads(t);
    }
    if let Some(c) = cores {
        builder = builder.cores(c);
    }
    if let Some(every) = parsed_flag::<usize>(args, "--observe")? {
        builder = builder
            .observe_every(every)
            .observer(Box::new(PrintObserver));
    }
    // Telemetry is opt-in: --trace turns on both the metrics registry
    // and the span tracer for this run (results are bit-identical
    // either way).
    let trace_path = flag_value(args, "--trace");
    if trace_path.is_some() {
        telemetry::metrics().set_enabled(true);
        telemetry::tracer().start();
    }
    // Measured-roofline profiling is opt-in and purely post-run: the
    // finished chains are projected onto the paper's roofline after the
    // run, so results are bit-identical with or without the flag.
    if has_flag(args, "--profile") {
        mc2a::engine::profile::set_enabled(true);
    }
    let mut engine = builder.build()?;
    println!(
        "workload={} nodes={} edges={} algo={} sampler={} backend={} steps={steps} chains={chains}",
        engine.workload_name().unwrap_or("?"),
        engine.model().num_vars(),
        engine.model().interaction().num_edges(),
        engine.spec().algo.name(),
        engine.spec().sampler.spec(),
        engine.backend_name(),
    );
    let metrics = engine.run()?;
    if let Some(summary) = engine.anneal_describe() {
        println!("{summary}");
    }
    if let Some(summary) = engine.temper_describe() {
        println!("{summary}");
    }
    for c in &metrics.chains {
        print!(
            "chain {}: best objective {:.2}, {} updates, {:?}",
            c.chain_id, c.best_objective, c.stats.updates, c.wall
        );
        if let Some(rep) = &c.sim {
            print!(
                ", {} cycles, {:.4} GS/s, {:.2} W (modeled)",
                rep.cycles,
                rep.gsps(&hw),
                rep.watts(&hw),
            );
        }
        println!();
        if let Some(rep) = &c.sim {
            println!(
                "  sim breakdown: CU util {:.2}, SU util {:.2}, sync overhead {:.1}%, \
                 stalls sync {} / xbar {} / mem {} / bank {}, {} xfer words",
                rep.cu_utilization(),
                rep.su_utilization(),
                100.0 * rep.sync_overhead(),
                rep.stall_sync,
                rep.stall_xbar,
                rep.stall_mem_bw,
                rep.stall_bank,
                rep.xfer_words,
            );
        }
        if let Some(mc) = &c.multicore {
            let util = mc
                .core_utilization()
                .iter()
                .map(|u| format!("{:.2}", u))
                .collect::<Vec<_>>()
                .join(" ");
            println!(
                "  {} cores: aggregate {:.4} GS/s, sync overhead {:.1}%, \
                 {} xfer words, cut edges {}, per-core utilization [{util}]",
                mc.cores(),
                mc.aggregate_gsps(&hw),
                100.0 * mc.sync_overhead_fraction(),
                mc.xfer_words,
                mc.cut_edges,
            );
        }
    }
    // Per-ensemble tempering diagnostics: one line per ensemble (the
    // report is shared by all of an ensemble's chains).
    let mut seen_ensembles = std::collections::HashSet::new();
    for c in &metrics.chains {
        if let Some(t) = &c.tempering {
            if seen_ensembles.insert(t.first_chain) {
                let rates = t
                    .swap_rates()
                    .iter()
                    .map(|r| format!("{r:.2}"))
                    .collect::<Vec<_>>()
                    .join(" ");
                println!(
                    "  ensemble @chain {}: pair swap rates [{rates}], {} round trips",
                    t.first_chain,
                    t.total_round_trips()
                );
            }
        }
    }
    println!(
        "best objective overall: {:.2}; software wall throughput {:.3e} updates/s",
        metrics.best_objective(),
        metrics.updates_per_sec()
    );
    if let Some(r) = metrics.split_r_hat() {
        println!("split R-hat {:.4}, min ESS {:.1}", r, metrics.min_ess());
    }
    if let Some(obs) = engine.observation() {
        println!("{}", obs.render_human());
    }
    if let Some(path) = flag_value(args, "--save-state") {
        // On accelerator backends `best_x` is the *final* state, whose
        // objective can trail `best_objective`; the checkpoint contract
        // pairs `best_objective` with `best_x`, so score each chain's
        // saved state directly and keep the best one.
        let (best, objective) = metrics
            .chains
            .iter()
            .map(|c| (c, engine.model().objective(&c.best_x)))
            .reduce(|a, b| if b.1 > a.1 { b } else { a })
            .ok_or_else(|| Mc2aError::InvalidConfig("no chains to checkpoint".into()))?;
        let ck = Checkpoint {
            seed,
            steps: prior_steps + best.steps,
            best_objective: objective,
            best_x: best.best_x.clone(),
            anneal: engine.anneal_state(),
            temper: engine.temper_state(),
            workload: engine.workload_name().map(str::to_string),
            sampler: Some(engine.spec().sampler.spec()),
            chains: Some(chains),
        };
        ck.save(&path)?;
        println!(
            "saved checkpoint to {path} (chain {}, state objective {objective:.2})",
            best.chain_id
        );
    }
    if let Some(path) = &trace_path {
        let tracer = telemetry::tracer();
        tracer.stop();
        tracer
            .write(path)
            .map_err(|e| Mc2aError::Checkpoint(format!("writing trace {path}: {e}")))?;
        println!(
            "wrote {} trace events to {path} (chrome://tracing / Perfetto)",
            tracer.event_count()
        );
    }
    Ok(())
}

fn cmd_workloads() {
    println!("{:<14} {:<22} summary", "name", "aliases");
    for e in registry::REGISTRY {
        println!(
            "{:<14} {:<22} {}{}",
            e.name,
            e.aliases.join(", "),
            e.summary,
            if e.heavy { "  [heavy]" } else { "" }
        );
    }
}

/// Parsed fields of one measured observation from a `--observed`
/// profile document, kept alongside its raw JSON for re-embedding.
struct ObservedEntry {
    raw: String,
    fields: Vec<(String, proto::JVal)>,
}

impl ObservedEntry {
    fn num(&self, key: &str) -> Option<f64> {
        self.fields.iter().find_map(|(k, v)| match v {
            proto::JVal::Num(n) if k == key => Some(*n),
            _ => None,
        })
    }

    fn str(&self, key: &str) -> Option<&str> {
        self.fields.iter().find_map(|(k, v)| match v {
            proto::JVal::Str(s) if k == key => Some(s.as_str()),
            _ => None,
        })
    }
}

/// Load a `PROFILE_roofline.json` document and keep the observations
/// of one workload.
fn load_observed(path: &str, workload: &str) -> Result<Vec<ObservedEntry>, Mc2aError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Mc2aError::InvalidConfig(format!("reading {path}: {e}")))?;
    let mut out = Vec::new();
    for raw in mc2a::roofline::observe::extract_observations(&text) {
        let fields = proto::parse_flat_object(&raw).map_err(|e| {
            Mc2aError::InvalidConfig(format!("parsing observation in {path}: {e}"))
        })?;
        let entry = ObservedEntry { raw, fields };
        if entry.str("workload") == Some(workload) {
            out.push(entry);
        }
    }
    Ok(out)
}

fn cmd_roofline(args: &[String]) -> Result<(), Mc2aError> {
    let format = flag_value(args, "--format").unwrap_or_else(|| "human".into());
    if format != "human" && format != "json" {
        return Err(Mc2aError::InvalidConfig(format!(
            "unknown format {format:?} (human|json)"
        )));
    }
    let observed_path = flag_value(args, "--observed");
    if let Some(wname) = flag_value(args, "--workload") {
        let wl = registry::lookup(&wname)?;
        let hw = HwConfig::paper_default();
        let p = WorkloadProfile::from_model(wl.model.as_ref(), wl.algorithm);
        let r = roofline::evaluate(&hw, &p);
        let multicore = match parsed_flag::<usize>(args, "--cores")? {
            Some(cores) => {
                let g = wl.model.interaction();
                mc2a::sim::multicore::validate_shard_config(g.num_nodes(), wl.algorithm, cores)
                    .map_err(Mc2aError::InvalidConfig)?;
                let bf = mc2a::graph::partition_balanced(g, cores).boundary_fraction(g);
                let m = roofline::evaluate_multicore(&MultiHwConfig::new(hw, cores), &p, bf);
                Some((m, bf))
            }
            None => None,
        };
        let observed = match &observed_path {
            Some(path) => load_observed(path, wl.name)?,
            None => Vec::new(),
        };
        if format == "json" {
            let obs: Vec<&str> = observed.iter().map(|e| e.raw.as_str()).collect();
            let mc = match &multicore {
                Some((m, bf)) => format!(
                    ",\"cores\":{},\"multicore_tp_gsps\":{},\"linear_tp_gsps\":{},\
                     \"xbar_roof\":{},\"boundary_fraction\":{},\"interconnect_bound\":{}",
                    m.cores, m.tp_gsps, m.linear_tp, m.xbar_roof, bf, m.interconnect_bound
                ),
                None => String::new(),
            };
            println!(
                "{{\"workload\":\"{}\",\"ci\":{},\"mi\":{},\"dist\":{},\"spatial\":{},\
                 \"tp_gsps\":{},\"su_roof\":{},\"cu_roof\":{},\"mem_roof\":{},\
                 \"bottleneck\":\"{:?}\"{mc},\"observed\":[{}]}}",
                wl.name,
                p.ci,
                p.mi,
                p.dist_size,
                p.spatial,
                r.tp_gsps,
                r.su_roof,
                r.cu_roof,
                r.mem_roof,
                r.bottleneck,
                obs.join(","),
            );
            return Ok(());
        }
        println!(
            "workload={} CI={:.5} MI={:.5} dist={:.0} mode={}",
            wl.name,
            p.ci,
            p.mi,
            p.dist_size,
            if p.spatial { "spatial" } else { "temporal" }
        );
        println!(
            "TP={:.4} GS/s (SU {:.4} / CU {:.4} / MEM {:.4}) bottleneck={:?}",
            r.tp_gsps, r.su_roof, r.cu_roof, r.mem_roof, r.bottleneck
        );
        if let Some((m, bf)) = &multicore {
            println!(
                "C={} cores: TP={:.4} GS/s (linear {:.4} / xbar roof {:.4}, \
                 boundary fraction {:.3}) bottleneck={}",
                m.cores,
                m.tp_gsps,
                m.linear_tp,
                m.xbar_roof,
                bf,
                if m.interconnect_bound {
                    "SharedInterconnect"
                } else {
                    "PerCoreEnvelope"
                }
            );
        }
        if observed_path.is_some() && observed.is_empty() {
            println!("observed: no measurements for {} in the profile document", wl.name);
        }
        // Measured-vs-predicted comparison rows, one per observation.
        for e in &observed {
            let fnum = |v: Option<f64>| match v {
                Some(x) => format!("{x:.4}"),
                None => "n/a".to_string(),
            };
            let drift = match e.num("drift_pct") {
                Some(d) => format!("{d:+.1}%"),
                None => "n/a".to_string(),
            };
            println!(
                "observed[{}] measured {} GS/s vs predicted {} GS/s  drift {}  \
                 verdict {} (model: {})",
                e.str("backend").unwrap_or("?"),
                fnum(e.num("measured_gsps")),
                fnum(e.num("predicted_gsps")),
                drift,
                e.str("verdict").unwrap_or("?"),
                e.str("predicted_verdict").unwrap_or("?"),
            );
        }
    } else if has_flag(args, "--cores") {
        return Err(Mc2aError::InvalidConfig(
            "--cores needs a workload point to evaluate (add --workload <name>)".into(),
        ));
    } else if observed_path.is_some() || format == "json" {
        return Err(Mc2aError::InvalidConfig(
            "--observed/--format json need a workload point (add --workload <name>)".into(),
        ));
    } else {
        println!("{}", bench::fig6());
    }
    Ok(())
}

/// `mc2a profile`: sweep one-or-all registry workloads across the
/// execution backends with the measured-roofline profiler on, emit
/// each [`mc2a::roofline::RooflineObservation`], and drop
/// `PROFILE_roofline.json` at the repo root for `mc2a roofline
/// --observed` and CI drift gating.
fn cmd_profile(args: &[String]) -> Result<(), Mc2aError> {
    let all = has_flag(args, "--all");
    let wname = flag_value(args, "--workload");
    if all == wname.is_some() {
        return Err(Mc2aError::InvalidConfig(
            "profile needs exactly one target: --workload <name> or --all".into(),
        ));
    }
    let format = flag_value(args, "--format").unwrap_or_else(|| "human".into());
    if format != "human" && format != "json" {
        return Err(Mc2aError::InvalidConfig(format!(
            "unknown format {format:?} (human|json)"
        )));
    }
    let steps: usize = parsed_flag(args, "--steps")?.unwrap_or(40);
    let chains: usize = parsed_flag(args, "--chains")?.unwrap_or(2);
    let seed: u64 = parsed_flag(args, "--seed")?.unwrap_or(1);
    let cores: usize = parsed_flag(args, "--cores")?.unwrap_or(2);
    let max_drift: Option<f64> = parsed_flag(args, "--max-drift")?;
    let backends: Vec<String> = flag_value(args, "--backends")
        .unwrap_or_else(|| "sw,batched,sim,multicore".into())
        .split(',')
        .map(|b| b.trim().to_string())
        .filter(|b| !b.is_empty())
        .collect();
    if backends.is_empty() {
        return Err(Mc2aError::InvalidConfig("--backends got an empty list".into()));
    }

    let mut names: Vec<String> = Vec::new();
    if let Some(name) = &wname {
        names.push(registry::lookup(name)?.name.to_string());
    } else {
        for e in registry::REGISTRY {
            if !e.heavy {
                names.push(e.name.to_string());
            }
        }
    }

    mc2a::engine::profile::set_enabled(true);
    let hw = HwConfig::paper_default();
    let mut observations = Vec::new();
    let mut skipped = 0usize;
    for name in &names {
        for backend in &backends {
            if backend == "multicore" {
                // Sweeps skip unshardable workload × core combinations
                // instead of erroring, mirroring `mc2a check`.
                let wl = registry::lookup(name)?;
                if mc2a::sim::multicore::validate_shard_config(
                    wl.model.num_vars(),
                    wl.algorithm,
                    cores,
                )
                .is_err()
                {
                    skipped += 1;
                    continue;
                }
            }
            let mut builder = Engine::for_workload(name)?.steps(steps).chains(chains).seed(seed);
            builder = match backend.as_str() {
                "sw" | "software" => builder.software(),
                "batched" => builder.batched(),
                "sim" | "accel" | "accelerator" => builder.accelerator(hw),
                "multicore" => builder.multicore(hw).cores(cores),
                other => {
                    return Err(Mc2aError::InvalidConfig(format!(
                        "unknown backend {other:?} (sw|batched|sim|multicore)"
                    )))
                }
            };
            let mut engine = builder.build()?;
            engine.run()?;
            let obs = engine.observation().cloned().ok_or_else(|| {
                Mc2aError::InvalidConfig("profiling produced no observation".into())
            })?;
            if format == "human" {
                println!("{}", obs.render_human());
            }
            observations.push(obs);
        }
    }

    let body: Vec<String> = observations.iter().map(|o| o.to_json()).collect();
    let doc = format!("{{\"profile\":[{}],\"skipped\":{skipped}}}", body.join(","));
    if format == "json" {
        println!("{doc}");
    }
    let note = bench::write_bench_artifact("PROFILE_roofline.json", &doc);
    eprintln!(
        "mc2a profile: {} observation(s), {skipped} skipped; {note}",
        observations.len()
    );

    // The CI drift gate: only cycle-domain (simulated) observations
    // are deterministic enough to gate on; wall-clock backends vary
    // with host load. NaN drift (no prediction) also fails the gate.
    if let Some(tol) = max_drift {
        let violations: Vec<String> = observations
            .iter()
            .filter(|o| {
                let within = o.drift.drift_pct.abs() <= tol;
                o.cycle_domain && !within
            })
            .map(|o| {
                format!(
                    "{} on {}: measured-vs-predicted drift {:+.1}% exceeds ±{tol}%",
                    o.workload, o.backend, o.drift.drift_pct
                )
            })
            .collect();
        if !violations.is_empty() {
            return Err(Mc2aError::InvalidConfig(format!(
                "model drift gate failed:\n  {}",
                violations.join("\n  ")
            )));
        }
    }
    Ok(())
}

/// Parse the `--hw` argument of `mc2a check`: the presets `paper` /
/// `toy`, or a comma-separated `key=value` list applied on top of the
/// paper-default configuration (keys: t, k, s, m, b/bw, banks, regs,
/// lut, lutbits, maxdist, clock).
fn parse_hw(spec: &str) -> Result<HwConfig, Mc2aError> {
    let mut hw = match spec {
        "paper" => return Ok(HwConfig::paper_default()),
        "toy" => return Ok(HwConfig::fig10_toy()),
        _ => HwConfig::paper_default(),
    };
    for kv in spec.split(',') {
        let (key, val) = kv.split_once('=').ok_or_else(|| {
            Mc2aError::InvalidConfig(format!(
                "bad --hw field {kv:?} (want key=value, or the presets paper|toy)"
            ))
        })?;
        let bad = || Mc2aError::InvalidConfig(format!("bad --hw value {val:?} for key {key:?}"));
        if key == "clock" {
            hw.clock_ghz = val.parse().map_err(|_| bad())?;
            continue;
        }
        let n: usize = val.parse().map_err(|_| bad())?;
        match key {
            "t" => hw.t = n,
            "k" => hw.k = n,
            "s" => hw.s = n,
            "m" => hw.m = n,
            "b" | "bw" => hw.bw_words = n,
            "banks" => hw.rf_banks = n,
            "regs" => hw.rf_regs_per_bank = n,
            "lut" => hw.lut_size = n,
            "lutbits" => hw.lut_bits = n as u32,
            "maxdist" => hw.max_dist_size = n,
            other => {
                return Err(Mc2aError::InvalidConfig(format!(
                    "unknown --hw key {other:?} \
                     (t, k, s, m, b/bw, banks, regs, lut, lutbits, maxdist, clock)"
                )))
            }
        }
    }
    hw.validate().map_err(Mc2aError::InvalidHardware)?;
    Ok(hw)
}

/// One `mc2a check` record: the findings for a single analysis target
/// (one workload × algorithm × core count, one chromatic schedule, or
/// one sampler/hardware pairing).
struct CheckRecord {
    workload: String,
    target: String,
    report: mc2a::compiler::analysis::Report,
}

fn cmd_check(args: &[String]) -> Result<(), Mc2aError> {
    use mc2a::compiler::analysis;

    let all = has_flag(args, "--all");
    let wname = flag_value(args, "--workload");
    if all == wname.is_some() {
        return Err(Mc2aError::InvalidConfig(
            "check needs exactly one target: --workload <name> or --all".into(),
        ));
    }
    let hw = parse_hw(&flag_value(args, "--hw").unwrap_or_else(|| "paper".into()))?;
    let format = flag_value(args, "--format").unwrap_or_else(|| "human".into());
    if format != "human" && format != "json" {
        return Err(Mc2aError::InvalidConfig(format!(
            "unknown format {format:?} (human|json)"
        )));
    }
    let algo_filter = match flag_value(args, "--algo") {
        Some(a) => Some(AlgoKind::parse(&a).ok_or_else(|| {
            Mc2aError::InvalidConfig(format!("unknown algorithm {a:?} (mh|gibbs|bg|ag|pas)"))
        })?),
        None => None,
    };
    let sampler = match flag_value(args, "--sampler") {
        Some(s) => {
            Some(SamplerKind::parse(&s).map_err(|e| Mc2aError::InvalidConfig(e.to_string()))?)
        }
        None => None,
    };
    let cores_filter: Option<usize> = parsed_flag(args, "--cores")?;

    let algos: Vec<AlgoKind> = match algo_filter {
        Some(a) => vec![a],
        None => vec![
            AlgoKind::Mh,
            AlgoKind::Gibbs,
            AlgoKind::BlockGibbs,
            AlgoKind::AsyncGibbs,
            AlgoKind::Pas,
        ],
    };
    let core_counts: Vec<usize> = match cores_filter {
        Some(c) => vec![c],
        None => vec![1, 4],
    };
    // Only an explicitly pinned (algo, cores) pair turns an unshardable
    // combination into a hard error; sweeps skip it and keep going.
    let pinned = algo_filter.is_some() && cores_filter.is_some();

    let mut workloads = Vec::new();
    if let Some(name) = &wname {
        workloads.push(registry::lookup(name)?);
    } else {
        let heavy = has_flag(args, "--heavy");
        for e in registry::REGISTRY {
            if heavy || !e.heavy {
                workloads.push(e.build());
            }
        }
    }

    let mut records: Vec<CheckRecord> = Vec::new();
    if let Some(s) = sampler {
        records.push(CheckRecord {
            workload: "-".into(),
            target: format!("sampler {}", s.spec()),
            report: analysis::analyze_sampler(s, &hw),
        });
    }
    let mut skipped = 0usize;
    for wl in &workloads {
        let model = wl.model.as_ref();
        records.push(CheckRecord {
            workload: wl.name.to_string(),
            target: "chromatic".into(),
            report: analysis::analyze_chromatic(model),
        });
        for &algo in &algos {
            for &cores in &core_counts {
                if cores > 1 {
                    if let Err(e) = mc2a::sim::multicore::validate_shard_config(
                        model.num_vars(),
                        algo,
                        cores,
                    ) {
                        if pinned {
                            return Err(Mc2aError::InvalidConfig(e));
                        }
                        skipped += 1;
                        continue;
                    }
                }
                let flips = wl.pas_flips.max(1);
                let report = if cores == 1 {
                    let program = mc2a::compiler::compile(model, algo, &hw, flips)?;
                    analysis::analyze_program(
                        &program,
                        model,
                        &hw,
                        analysis::algo_expects_full_coverage(algo),
                    )
                } else {
                    let mhw = MultiHwConfig::new(hw, cores);
                    analysis::analyze_ensemble(model, algo, &mhw, flips)?
                };
                records.push(CheckRecord {
                    workload: wl.name.to_string(),
                    target: format!("{} x{}", algo.name(), cores),
                    report,
                });
            }
        }
    }

    let total = |sev| -> usize { records.iter().map(|r| r.report.count(sev)).sum() };
    let errors = total(analysis::Severity::Error);
    let warnings = total(analysis::Severity::Warning);
    let infos = total(analysis::Severity::Info);

    if format == "json" {
        let items: Vec<String> = records
            .iter()
            .map(|r| {
                format!(
                    "{{\"workload\":\"{}\",\"target\":\"{}\",\"errors\":{},\"warnings\":{},\
                     \"infos\":{},\"diagnostics\":{}}}",
                    r.workload,
                    r.target,
                    r.report.count(analysis::Severity::Error),
                    r.report.count(analysis::Severity::Warning),
                    r.report.count(analysis::Severity::Info),
                    r.report.to_json()
                )
            })
            .collect();
        println!(
            "{{\"records\":[{}],\"errors\":{errors},\"warnings\":{warnings},\
             \"infos\":{infos},\"skipped\":{skipped}}}",
            items.join(",")
        );
    } else {
        for r in &records {
            if r.report.diagnostics.is_empty() {
                continue;
            }
            println!("== {} · {}", r.workload, r.target);
            println!("{}", r.report.render_human());
        }
        println!(
            "checked {} targets across {} workload(s): {errors} error(s), \
             {warnings} warning(s), {infos} info(s){}",
            records.len(),
            workloads.len(),
            if skipped > 0 {
                format!(" ({skipped} unshardable combinations skipped)")
            } else {
                String::new()
            }
        );
    }

    if errors > 0 {
        let mut diagnostics = Vec::new();
        for r in &records {
            diagnostics.extend(r.report.errors());
        }
        return Err(Mc2aError::InvalidProgram { diagnostics });
    }
    Ok(())
}

fn cmd_runtime_check(args: &[String]) -> Result<(), Mc2aError> {
    let dir = flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    match Runtime::load(&dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            println!("artifacts: {:?}", rt.names());
            print!("{}", bench::measured_cpu_rows(&rt));
            Ok(())
        }
        Err(e) => Err(Mc2aError::RuntimeUnavailable(format!("{e:#}"))),
    }
}

fn cmd_serve(args: &[String]) -> Result<(), Mc2aError> {
    let addr = flag_value(args, "--addr").unwrap_or_else(|| "127.0.0.1:7477".into());
    let dir = flag_value(args, "--dir").map(PathBuf::from);
    let threads: usize = parsed_flag(args, "--threads")?.unwrap_or(0);
    let recover = has_flag(args, "--recover");
    let force_backend = match flag_value(args, "--force-backend") {
        Some(b) => Some(ServeBackend::parse(&b).ok_or_else(|| {
            Mc2aError::InvalidConfig(format!("unknown backend {b:?} (sw|sim)"))
        })?),
        None => None,
    };
    if recover && dir.is_none() {
        return Err(Mc2aError::InvalidConfig(
            "--recover needs the job directory that holds the envelopes (add --dir DIR)".into(),
        ));
    }
    if force_backend.is_some() && !recover {
        return Err(Mc2aError::InvalidConfig(
            "--force-backend only applies when recovering jobs (add --recover)".into(),
        ));
    }
    // Admin surface: a Prometheus scrape endpoint on its own port, and
    // an optional whole-process span trace written at clean shutdown.
    if let Some(maddr) = flag_value(args, "--metrics-addr") {
        telemetry::metrics().set_enabled(true);
        let bound = net::spawn_metrics_http(&maddr)?;
        eprintln!("mc2a serve: metrics on http://{bound}/metrics");
    }
    let trace_path = flag_value(args, "--trace");
    if trace_path.is_some() {
        telemetry::metrics().set_enabled(true);
        telemetry::tracer().start();
    }
    let cfg = JobServerConfig { threads, dir };
    let server =
        if recover { JobServer::recover_with(cfg, force_backend)? } else { JobServer::new(cfg)? };
    net::serve(server, &addr)?;
    if let Some(path) = &trace_path {
        let tracer = telemetry::tracer();
        tracer.stop();
        tracer
            .write(path)
            .map_err(|e| Mc2aError::Checkpoint(format!("writing trace {path}: {e}")))?;
        eprintln!("mc2a serve: wrote {} trace events to {path}", tracer.event_count());
    }
    Ok(())
}

/// The `--job N` flag, required by result/cancel/stream.
fn required_job(args: &[String]) -> Result<u64, Mc2aError> {
    parsed_flag::<u64>(args, "--job")?
        .ok_or_else(|| Mc2aError::InvalidConfig("--job N is required".into()))
}

/// Print the server's response line; non-`ok` responses exit with
/// status 2 so shell scripts can branch on failure.
fn finish_response(response: String) -> Result<(), Mc2aError> {
    println!("{response}");
    if proto::response_is_ok(&response) {
        Ok(())
    } else {
        Err(Mc2aError::Server(format!("request failed: {response}")))
    }
}

fn cmd_client(args: &[String]) -> Result<(), Mc2aError> {
    const VERBS: [&str; 9] = [
        "submit", "status", "result", "cancel", "stream", "metrics", "stats", "shutdown", "ping",
    ];
    let addr = flag_value(args, "--addr").unwrap_or_else(|| "127.0.0.1:7477".into());
    let retries: u32 = parsed_flag(args, "--connect-retries")?.unwrap_or(0);
    let verb = args
        .iter()
        .map(String::as_str)
        .find(|a| VERBS.contains(a))
        .ok_or_else(|| {
            Mc2aError::InvalidConfig(
                "client needs a verb: submit|status|result|cancel|stream|metrics|stats|\
                 shutdown|ping"
                    .into(),
            )
        })?;
    let line = match verb {
        "submit" => {
            let workload = flag_value(args, "--workload").ok_or_else(|| {
                Mc2aError::InvalidConfig("submit requires --workload <name>".into())
            })?;
            let mut spec = JobSpec::new(workload);
            if let Some(v) = parsed_flag(args, "--steps")? {
                spec.steps = v;
            }
            if let Some(v) = parsed_flag(args, "--chains")? {
                spec.chains = v;
            }
            if let Some(v) = parsed_flag(args, "--seed")? {
                spec.seed = v;
            }
            if let Some(v) = parsed_flag(args, "--beta")? {
                spec.beta = v;
            }
            if let Some(v) = parsed_flag(args, "--observe")? {
                spec.observe_every = v;
            }
            spec.pas_flips = parsed_flag(args, "--pas-flips")?;
            if let Some(a) = flag_value(args, "--algo") {
                spec.algo = Some(AlgoKind::parse(&a).ok_or_else(|| {
                    Mc2aError::InvalidConfig(format!("unknown algo {a:?} (mh|gibbs|bg|ag|pas)"))
                })?);
            }
            if let Some(s) = flag_value(args, "--sampler") {
                spec.sampler = SamplerKind::parse(&s)
                    .map_err(|e| Mc2aError::InvalidConfig(e.to_string()))?;
            }
            if let Some(b) = flag_value(args, "--backend") {
                spec.backend = ServeBackend::parse(&b).ok_or_else(|| {
                    Mc2aError::InvalidConfig(format!("unknown backend {b:?} (sw|sim)"))
                })?;
            }
            if let Some(p) = flag_value(args, "--priority") {
                spec.priority = Priority::parse(&p).ok_or_else(|| {
                    Mc2aError::InvalidConfig(format!(
                        "unknown priority {p:?} (low|normal|high)"
                    ))
                })?;
            }
            if has_flag(args, "--trace") {
                spec.trace = true;
            }
            if has_flag(args, "--profile") {
                spec.profile = true;
            }
            proto::submit_line(&spec)
        }
        "status" => proto::status_line(parsed_flag(args, "--job")?),
        "result" => {
            let job = required_job(args)?;
            let line = proto::result_line(job);
            if has_flag(args, "--wait") {
                // Poll until the job leaves the queue (or the deadline
                // passes); every other response kind is final.
                let timeout: u64 = parsed_flag(args, "--timeout")?.unwrap_or(600);
                let deadline = std::time::Instant::now() + Duration::from_secs(timeout);
                loop {
                    let response = net::client_request(&addr, &line, retries)?;
                    if proto::response_kind(&response).as_deref() != Some("not-finished") {
                        return finish_response(response);
                    }
                    if std::time::Instant::now() >= deadline {
                        return Err(Mc2aError::Server(format!(
                            "timed out after {timeout}s waiting for job {job}"
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(300));
                }
            }
            line
        }
        "cancel" => proto::cancel_line(required_job(args)?),
        "stream" => {
            return net::client_stream(&addr, &proto::stream_line(required_job(args)?), |l| {
                println!("{l}");
                true
            });
        }
        "metrics" => proto::metrics_line(),
        "stats" => proto::stats_line(),
        "shutdown" => proto::shutdown_line(),
        "ping" => proto::ping_line(),
        _ => unreachable!("verb is drawn from VERBS"),
    };
    finish_response(net::client_request(&addr, &line, retries)?)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("table1") => {
            println!("{}", bench::table1(has_flag(&args[1..], "--full")));
            Ok(())
        }
        Some("bench") => cmd_bench(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("client") => cmd_client(&args[1..]),
        Some("workloads") => {
            cmd_workloads();
            Ok(())
        }
        Some("check") => cmd_check(&args[1..]),
        Some("profile") => cmd_profile(&args[1..]),
        Some("roofline") => cmd_roofline(&args[1..]),
        Some("dse") => {
            println!("{}", bench::fig11());
            Ok(())
        }
        Some("runtime-check") => cmd_runtime_check(&args[1..]),
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}
