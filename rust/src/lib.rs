//! # MC²A — Algorithm-Hardware Co-Design for MCMC Acceleration
//!
//! Reproduction of *"MC²A: Enabling Algorithm-Hardware Co-Design for
//! Efficient Markov Chain Monte Carlo Acceleration"* (Zhao et al., 2025)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate provides:
//!
//! * [`energy`] — discrete energy models (Ising/Potts grids, Bayesian
//!   networks, combinatorial-optimization graphs, RBMs) behind the common
//!   [`energy::EnergyModel`] trait.
//! * [`mcmc`] — the MCMC algorithm zoo the paper evaluates: MH, Gibbs,
//!   Block Gibbs, Asynchronous Gibbs and the gradient-based PAS sampler,
//!   plus the CDF and Gumbel-max categorical samplers.
//! * [`roofline`] — the paper's 3D roofline model (Compute Intensity ×
//!   Memory Intensity × Throughput) and the design-space exploration that
//!   selects the accelerator parameters (Fig. 6, Fig. 11).
//! * [`isa`] / [`compiler`] / [`sim`] — the MC²A accelerator itself: the
//!   VLIW instruction set (Fig. 7c), the scheduling compiler, and a
//!   cycle-accurate simulator of the 4-stage pipeline with tree-CU,
//!   reconfigurable Gumbel SU, crossbar and multi-bank register file.
//! * [`baselines`] — calibrated models of the comparison platforms
//!   (CPU/GPU/TPU and the SPU/PGMA/CoopMC/sIM/PROCA accelerators).
//! * [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from Rust; this
//!   is the *measured* software baseline path (Python never runs at
//!   request time).
//! * [`coordinator`] — L3 chain orchestration: backend routing, chain
//!   scheduling, convergence tracking, metrics.
//! * [`workloads`] — the Table I benchmark suite generators.
//! * [`bench`] — harnesses that regenerate every table and figure of the
//!   paper's evaluation section.

pub mod baselines;
pub mod bench;
pub mod compiler;
pub mod coordinator;
pub mod energy;
pub mod graph;
pub mod isa;
pub mod mcmc;
pub mod rng;
pub mod roofline;
pub mod runtime;
pub mod sim;
pub mod workloads;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
