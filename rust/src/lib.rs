//! # MC²A — Algorithm-Hardware Co-Design for MCMC Acceleration
//!
//! Reproduction of *"MC²A: Enabling Algorithm-Hardware Co-Design for
//! Efficient Markov Chain Monte Carlo Acceleration"* (Zhao et al., 2025)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The public entry point is the [`engine`] — a builder façade over
//! pluggable execution backends:
//!
//! ```no_run
//! use mc2a::engine::Engine;
//!
//! let metrics = Engine::for_workload("optsicom")?
//!     .steps(500)
//!     .chains(4)
//!     .build()?
//!     .run()?;
//! println!("best cut: {}", metrics.best_objective());
//! # Ok::<(), mc2a::engine::Mc2aError>(())
//! ```
//!
//! Module map:
//!
//! * [`engine`] — **the public API**: [`engine::EngineBuilder`] run
//!   configuration, the [`engine::ExecutionBackend`] trait with
//!   software / batched-software / accelerator-sim / sharded
//!   multi-core / PJRT-runtime implementations, the
//!   [`engine::scheduler`] work-stealing thread pool that multiplexes
//!   `chains / batch` work items over a fixed worker set, the
//!   [`engine::ChainObserver`] streaming-diagnostics API with
//!   optional cold-chain restarts, [`engine::Checkpoint`]
//!   save/resume, the typed [`engine::Mc2aError`], the
//!   named-workload [`engine::registry`], and [`engine::server`] —
//!   the persistent multi-tenant job server (`mc2a serve`) that
//!   multiplexes heterogeneous jobs over one shared priority-aware
//!   pool with checkpoint-backed crash recovery.
//! * [`energy`] — discrete energy models (Ising/Potts grids, Bayesian
//!   networks, combinatorial-optimization graphs, RBMs) behind the common
//!   [`energy::EnergyModel`] trait, with batched (structure-of-arrays)
//!   conditional-energy kernels for the many-chain path.
//! * [`mcmc`] — the MCMC algorithm zoo the paper evaluates: MH, Gibbs,
//!   Block Gibbs, Asynchronous Gibbs and the gradient-based PAS sampler,
//!   plus the CDF and Gumbel-max categorical samplers (scalar and
//!   batched), the SoA [`mcmc::ChainBatch`] many-chain state, and the
//!   convergence metrics (accuracy traces, split R-hat, ESS).
//! * [`roofline`] — the paper's 3D roofline model (Compute Intensity ×
//!   Memory Intensity × Throughput) and the design-space exploration that
//!   selects the accelerator parameters (Fig. 6, Fig. 11).
//! * [`isa`] / [`compiler`] / [`sim`] — the MC²A accelerator itself: the
//!   VLIW instruction set (Fig. 7c), the scheduling compiler (single-
//!   core and per-shard), and a cycle-accurate simulator of the 4-stage
//!   pipeline with tree-CU, reconfigurable Gumbel SU, crossbar and
//!   multi-bank register file — plus [`sim::multicore`], the sharded
//!   C-core system of §II-D with its shared-crossbar contention model.
//! * [`baselines`] — calibrated models of the comparison platforms
//!   (CPU/GPU/TPU and the SPU/PGMA/CoopMC/sIM/PROCA accelerators).
//! * [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from Rust
//!   (behind the `xla-runtime` feature; a stub otherwise).
//! * [`coordinator`] — per-chain results and multi-chain aggregate
//!   metrics produced by the engine.
//! * [`workloads`] — the Table I benchmark suite generators.
//! * [`bench`] — harnesses that regenerate every table and figure of the
//!   paper's evaluation section.

pub mod baselines;
pub mod bench;
pub mod compiler;
pub mod coordinator;
pub mod energy;
pub mod engine;
pub mod graph;
pub mod isa;
pub mod mcmc;
pub mod rng;
pub mod roofline;
pub mod runtime;
pub mod sim;
pub mod workloads;

pub use engine::{Engine, EngineBuilder, ExecutionBackend, Mc2aError};

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
