//! Integration tests over the PJRT runtime: the AOT-compiled JAX/
//! Pallas artifacts must load, execute, and produce *numerically
//! correct* MCMC behavior from Rust (Python is gone at this point).
//!
//! These tests need `make artifacts` to have run; they are skipped
//! (with a message) when the artifact directory is missing so that
//! `cargo test` stays green on a fresh checkout.

use mc2a::energy::MaxCutModel;
use mc2a::graph::erdos_renyi_with_edges;
use mc2a::rng::Rng;
use mc2a::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e:#} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn manifest_covers_all_entrypoints() {
    let Some(rt) = runtime() else { return };
    for name in [
        "gumbel_sample",
        "ising_step",
        "ising_chain",
        "maxcut_pas_step",
        "maxcut_pas_chain",
    ] {
        assert!(rt.spec(name).is_some(), "missing artifact {name}");
    }
}

#[test]
fn input_validation_errors_are_clear() {
    let Some(rt) = runtime() else { return };
    // Wrong arity.
    assert!(rt.execute_f32("ising_step", &[&[0.0]]).is_err());
    // Wrong element count.
    let bad = vec![0.0f32; 16];
    let spec = rt.spec("gumbel_sample").unwrap().clone();
    assert_eq!(spec.inputs[0].dims, vec![64, 256]);
    let u = vec![0.5f32; 64 * 256];
    assert!(rt.execute_f32("gumbel_sample", &[&bad, &u, &[1.0]]).is_err());
    // Unknown artifact.
    assert!(rt.execute_f32("nope", &[]).is_err());
}

/// The Pallas Gumbel kernel through the whole AOT+PJRT path samples
/// the right distribution.
#[test]
fn gumbel_artifact_statistics() {
    let Some(rt) = runtime() else { return };
    let (b, n) = (64usize, 256usize);
    // Concentrate mass on 4 states with energies 0, 0.5, 1, 1.5;
    // everything else prohibitive.
    let mut e = vec![50.0f32; b * n];
    for row in 0..b {
        for s in 0..4 {
            e[row * n + s] = 0.5 * s as f32;
        }
    }
    let mut rng = Rng::new(0x6B);
    let mut counts = [0u64; 4];
    let draws = 40;
    for _ in 0..draws {
        let u: Vec<f32> = (0..b * n).map(|_| rng.uniform_open_f32()).collect();
        let out = rt.execute_f32("gumbel_sample", &[&e, &u, &[1.0]]).unwrap();
        for &idx in &out[0] {
            let k = idx as usize;
            assert!(k < 4, "sampled prohibited state {k}");
            counts[k] += 1;
        }
    }
    let total: u64 = counts.iter().sum();
    let z: f32 = (0..4).map(|s| (-0.5 * s as f32).exp()).sum();
    for s in 0..4 {
        let want = ((-0.5 * s as f32).exp() / z) as f64;
        let got = counts[s] as f64 / total as f64;
        assert!(
            (got - want).abs() < 0.03,
            "state {s}: got {got:.3} want {want:.3}"
        );
    }
}

/// Ising chain artifact: ordered phase stays ordered, hot phase mixes.
#[test]
fn ising_chain_artifact_phases() {
    let Some(rt) = runtime() else { return };
    let n = 64 * 64;
    let steps = 32;
    let mut rng = Rng::new(0x151);
    let run = |beta: f32, rng: &mut Rng| -> f32 {
        let spins = vec![1.0f32; n];
        let u: Vec<f32> = (0..steps * 2 * n).map(|_| rng.uniform_open_f32()).collect();
        let out = rt
            .execute_f32("ising_chain", &[&spins, &u, &[beta], &[1.0]])
            .unwrap();
        // last magnetization from the per-sweep trace
        out[1].last().copied().unwrap() / n as f32
    };
    let cold = run(1.5, &mut rng);
    let hot = run(0.0, &mut rng);
    assert!(cold > 0.8, "cold chain melted: m={cold}");
    assert!(hot.abs() < 0.2, "hot chain stayed ordered: m={hot}");
}

/// MaxCut PAS chain artifact improves the cut, and the ΔE semantics
/// agree with the Rust-side energy model.
#[test]
fn maxcut_chain_artifact_improves_cut() {
    let Some(rt) = runtime() else { return };
    let nn = 128;
    let g = erdos_renyi_with_edges(nn, 640, 0x14c);
    let mc = MaxCutModel::new(g.clone(), None);
    let mut adj = vec![0.0f32; nn * nn];
    for i in 0..nn {
        for &j in g.neighbors(i) {
            adj[i * nn + j as usize] = 1.0;
        }
    }
    let mut rng = Rng::new(0xCC);
    let x0: Vec<f32> = (0..nn).map(|_| rng.below(2) as f32).collect();
    let as_u32 = |x: &[f32]| x.iter().map(|&v| v as u32).collect::<Vec<_>>();
    let cut0 = mc.cut_weight(&as_u32(&x0));
    let mut x = x0;
    for _ in 0..4 {
        let u: Vec<f32> = (0..32 * nn).map(|_| rng.uniform_open_f32()).collect();
        let out = rt
            .execute_f32("maxcut_pas_chain", &[&adj, &x, &u, &[2.0]])
            .unwrap();
        x = out[0].clone();
        // labels must stay binary
        assert!(x.iter().all(|&v| v == 0.0 || v == 1.0));
    }
    let cut1 = mc.cut_weight(&as_u32(&x));
    assert!(cut1 > cut0, "cut did not improve: {cut0} → {cut1}");
}

/// Single ising_step and the 32-step chain must agree when fed the
/// same noise (the scan is just a fused loop).
#[test]
fn ising_step_composes_to_chain() {
    let Some(rt) = runtime() else { return };
    let n = 64 * 64;
    let steps = 32;
    let mut rng = Rng::new(0x5c);
    let spins0: Vec<f32> = (0..n).map(|_| if rng.below(2) == 1 { 1.0 } else { -1.0 }).collect();
    let u: Vec<f32> = (0..steps * 2 * n).map(|_| rng.uniform_open_f32()).collect();
    let beta = [0.6f32];
    let coupling = [1.0f32];

    let chain_out = rt
        .execute_f32("ising_chain", &[&spins0, &u, &beta, &coupling])
        .unwrap();

    let mut s = spins0;
    for t in 0..steps {
        let u0 = &u[t * 2 * n..t * 2 * n + n];
        let u1 = &u[t * 2 * n + n..(t + 1) * 2 * n];
        let out = rt
            .execute_f32("ising_step", &[&s, u0, u1, &beta, &coupling])
            .unwrap();
        s = out[0].clone();
    }
    assert_eq!(chain_out[0], s, "scan and unrolled steps disagree");
}
