//! Lane-width invariance and SIMD equivalence regression suite.
//!
//! The batched kernels process chains `LANES` at a time (remainder
//! chains take a scalar path), and the `simd` feature swaps the
//! portable lane kernels for AVX2/NEON intrinsics. Both axes must be
//! invisible: chain `c`'s trajectory is pinned to the scalar
//! `Chain` + `Rng::fork(seed, c)` reference bit-for-bit, for every
//! registry workload, every sampler, and batch widths straddling the
//! lane width (`K = 1, LANES−1, LANES, LANES+1, 2·LANES+3`).
//!
//! CI runs this file with `--features simd` (plus
//! `RUSTFLAGS="-C target-cpu=native"`) and without, so a divergence in
//! the intrinsic paths fails the same assertions as a divergence in
//! the portable ones.

use mc2a::energy::EnergyModel;
use mc2a::engine::registry;
use mc2a::mcmc::{
    build_algo, build_batch_algo, AlgoKind, BetaSchedule, Chain, ChainBatch, SamplerKind,
};
use mc2a::rng::{Rng, LANES};

const SEED: u64 = 0x51D_C0DE;
const SCHED: BetaSchedule = BetaSchedule::Constant(0.8);

/// Batch widths straddling the lane width: scalar-remainder only,
/// one-short, exact, one-over, and two-chunks-plus-remainder.
fn widths() -> [usize; 5] {
    [1, LANES - 1, LANES, LANES + 1, 2 * LANES + 3]
}

/// Scalar reference trajectories: chain `c` is independent of the
/// batch width by construction (`Rng::fork(seed, c)`), so one run at
/// the maximum width serves as the reference for every `K`.
fn scalar_reference(
    model: &dyn EnergyModel,
    algo_kind: AlgoKind,
    sampler: SamplerKind,
    flips: usize,
    steps: usize,
    max_k: usize,
) -> Vec<Vec<u32>> {
    (0..max_k)
        .map(|c| {
            let algo = build_algo(algo_kind, sampler, model, flips);
            let mut chain = Chain::with_rng(model, algo, SCHED, Rng::fork(SEED, c as u64));
            chain.run(steps);
            chain.x
        })
        .collect()
}

/// Assert the batched kernel reproduces the scalar reference at every
/// batch width in [`widths`].
fn assert_lane_width_invariant(
    label: &str,
    model: &dyn EnergyModel,
    algo_kind: AlgoKind,
    sampler: SamplerKind,
    flips: usize,
    steps: usize,
) {
    let max_k = *widths().iter().max().unwrap();
    let reference = scalar_reference(model, algo_kind, sampler, flips, steps, max_k);
    let mut gathered = Vec::new();
    for k in widths() {
        let mut algo = build_batch_algo(algo_kind, sampler, model, flips)
            .unwrap_or_else(|| panic!("{label}: no batched kernel for {algo_kind:?}"));
        let mut batch = ChainBatch::new(model, SCHED, SEED, 0, k, None);
        batch.run(algo.as_mut(), steps);
        for (c, want) in reference.iter().take(k).enumerate() {
            batch.chain_state(c, &mut gathered);
            assert_eq!(
                &gathered, want,
                "{label} ({algo_kind:?}/{}) K={k} chain {c}: batched state diverges from scalar",
                sampler.spec()
            );
        }
    }
}

/// The sampler grid: baseline CDF, exact Gumbel, the paper's default
/// LUT shape, and a non-default `lut:SIZE:BITS` shape.
fn samplers() -> [SamplerKind; 4] {
    [
        SamplerKind::Cdf,
        SamplerKind::Gumbel,
        SamplerKind::GumbelLut { size: 16, bits: 8 },
        SamplerKind::GumbelLut { size: 32, bits: 6 },
    ]
}

/// Every (non-heavy) registry workload × every sampler, Gibbs sweeps:
/// the broad equivalence net over real model structure (Bayes nets,
/// Potts grids, COP penalty models, RBM).
#[test]
fn every_registry_workload_and_sampler_is_lane_width_invariant() {
    for name in registry::names() {
        let entry = registry::find(name).unwrap();
        if entry.heavy {
            continue; // full-scale models; covered structurally by the small twin
        }
        let wl = entry.build();
        // Few steps: the bit-identity pin either breaks on the first
        // divergent draw or not at all; more steps only add runtime.
        let steps = if wl.nodes() > 1000 { 2 } else { 4 };
        for sampler in samplers() {
            assert_lane_width_invariant(
                name,
                wl.model.as_ref(),
                AlgoKind::Gibbs,
                sampler,
                1,
                steps,
            );
        }
    }
}

/// Each workload's Table-I-native algorithm (Block Gibbs, PAS, …) at
/// its configured PAS path length.
#[test]
fn native_algorithms_are_lane_width_invariant() {
    for name in registry::names() {
        let entry = registry::find(name).unwrap();
        if entry.heavy {
            continue;
        }
        let wl = entry.build();
        let steps = if wl.nodes() > 1000 { 2 } else { 4 };
        assert_lane_width_invariant(
            name,
            wl.model.as_ref(),
            wl.algorithm,
            SamplerKind::Gumbel,
            wl.pas_flips,
            steps,
        );
    }
}

/// The two kernels the lane refactor added last (batched Async-Gibbs
/// and batched PAS), exercised across samplers on a COP workload.
#[test]
fn async_gibbs_and_pas_are_lane_width_invariant_across_samplers() {
    let wl = registry::lookup("optsicom").unwrap();
    for sampler in samplers() {
        assert_lane_width_invariant(
            "optsicom",
            wl.model.as_ref(),
            AlgoKind::AsyncGibbs,
            sampler,
            1,
            4,
        );
    }
    for flips in [1usize, 3] {
        assert_lane_width_invariant(
            "optsicom",
            wl.model.as_ref(),
            AlgoKind::Pas,
            SamplerKind::Gumbel,
            flips,
            4,
        );
    }
}
