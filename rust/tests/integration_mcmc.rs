//! Cross-module integration tests for the MCMC software stack:
//! algorithm agreement on posterior marginals, COP convergence, and
//! the Fig. 5 profiler behaviors.

use mc2a::energy::{EnergyModel, MaxCutModel, PottsGrid};
use mc2a::graph::erdos_renyi_with_edges;
use mc2a::mcmc::{build_algo, run_to_accuracy, AlgoKind, BetaSchedule, Chain, SamplerKind};
use mc2a::workloads;

/// All exact-kernel algorithms must agree on Bayes-net marginals.
#[test]
fn algorithms_agree_on_earthquake_marginals() {
    let net = workloads::earthquake();
    let exact = net.exact_marginal(2); // P(Alarm)
    for algo in [AlgoKind::Gibbs, AlgoKind::BlockGibbs, AlgoKind::Pas] {
        let a = build_algo(algo, SamplerKind::Gumbel, &net, 2);
        let mut chain = Chain::new(&net, a, BetaSchedule::Constant(1.0), 0x7e57);
        chain.run(120_000);
        let emp = chain.marginal(2);
        assert!(
            (emp[1] - exact[1]).abs() < 0.01,
            "{algo:?}: {} vs exact {}",
            emp[1],
            exact[1]
        );
    }
}

/// MH with Metropolis acceptance must match Gibbs statistically.
#[test]
fn mh_matches_gibbs_on_ising() {
    let m = PottsGrid::new(4, 4, 2, 0.4);
    let run = |algo| {
        let a = build_algo(algo, SamplerKind::Gumbel, &m, 1);
        let mut chain = Chain::new(&m, a, BetaSchedule::Constant(1.0), 0xA);
        chain.run(60_000);
        let mut up = 0.0;
        for i in 0..m.num_vars() {
            up += chain.marginal(i)[1];
        }
        up / m.num_vars() as f64
    };
    let gibbs = run(AlgoKind::Gibbs);
    let mh = run(AlgoKind::Mh);
    assert!((gibbs - mh).abs() < 0.02, "gibbs={gibbs} mh={mh}");
}

/// The survey network's travel-mode marginal against enumeration.
#[test]
fn survey_travel_marginal() {
    let net = workloads::survey();
    let exact = net.exact_marginal(5);
    let a = build_algo(AlgoKind::BlockGibbs, SamplerKind::Gumbel, &net, 1);
    let mut chain = Chain::new(&net, a, BetaSchedule::Constant(1.0), 3);
    chain.run(150_000);
    let emp = chain.marginal(5);
    for s in 0..3 {
        assert!(
            (emp[s] - exact[s]).abs() < 0.012,
            "state {s}: {} vs {}",
            emp[s],
            exact[s]
        );
    }
}

/// PAS must converge in no more steps than MH on a frustrated COP —
/// the paper's observation 1 (Fig. 5a/b).
#[test]
fn pas_needs_fewer_steps_than_mh_on_maxcut() {
    let g = erdos_renyi_with_edges(80, 320, 0x5eed);
    let m = MaxCutModel::new(g, None);
    let schedule = BetaSchedule::Linear {
        from: 0.3,
        to: 3.0,
        steps: 300,
    };
    // Calibrate the reachable optimum.
    let cal = build_algo(AlgoKind::Pas, SamplerKind::Gumbel, &m, 8);
    let tr = run_to_accuracy(&m, cal, schedule, f64::INFINITY, 1500, 25, 1);
    let best = tr.points.last().unwrap().best_objective;

    let goal_steps = |algo: AlgoKind, flips: usize| -> u64 {
        let a = build_algo(algo, SamplerKind::Gumbel, &m, flips);
        let tr = run_to_accuracy(&m, a, schedule, f64::INFINITY, 1500, 10, 2);
        tr.points
            .iter()
            .find(|p| p.best_objective >= 0.92 * best)
            .map(|p| p.steps)
            .unwrap_or(u64::MAX)
    };
    let pas = goal_steps(AlgoKind::Pas, 8);
    let mh = goal_steps(AlgoKind::Mh, 8);
    assert!(
        pas <= mh,
        "PAS needed {pas} steps, MH needed {mh} — expected PAS ≤ MH"
    );
}

/// And PAS consumes more ops per update than Gibbs (the trade-off the
/// paper highlights: gradient info costs compute).
#[test]
fn pas_consumes_more_ops_per_update() {
    let g = erdos_renyi_with_edges(80, 320, 0x5eed);
    let m = MaxCutModel::new(g, None);
    let ops_per_update = |algo: AlgoKind| {
        let a = build_algo(algo, SamplerKind::Gumbel, &m, 8);
        let mut chain = Chain::new(&m, a, BetaSchedule::Constant(1.0), 5);
        chain.run(20);
        chain.stats.cost.ops as f64 / chain.stats.updates.max(1) as f64
    };
    assert!(ops_per_update(AlgoKind::Pas) > ops_per_update(AlgoKind::Gibbs));
}

/// Hardware-LUT sampler quality: chain marginals close to exact kernel
/// (Fig. 12's "16×8-bit is good enough" conclusion).
#[test]
fn lut_sampler_chain_quality() {
    let net = workloads::earthquake();
    let exact = net.exact_marginal(2);
    let a = build_algo(
        AlgoKind::Gibbs,
        SamplerKind::GumbelLut { size: 16, bits: 8 },
        &net,
        1,
    );
    let mut chain = Chain::new(&net, a, BetaSchedule::Constant(1.0), 0xb0);
    chain.run(120_000);
    let emp = chain.marginal(2);
    assert!(
        (emp[1] - exact[1]).abs() < 0.02,
        "{} vs {}",
        emp[1],
        exact[1]
    );
}

/// Full small-suite smoke: every Table I workload runs every compatible
/// algorithm for a few steps without panicking and makes progress.
#[test]
fn suite_smoke_all_algorithms() {
    for wl in workloads::suite_small() {
        for algo in [
            AlgoKind::Gibbs,
            AlgoKind::BlockGibbs,
            AlgoKind::AsyncGibbs,
            AlgoKind::Pas,
        ] {
            let a = build_algo(algo, SamplerKind::Gumbel, wl.model.as_ref(), 2);
            let mut chain = Chain::new(wl.model.as_ref(), a, BetaSchedule::Constant(0.8), 1);
            chain.run(3);
            assert!(chain.stats.updates > 0, "{} {:?}", wl.name, algo);
        }
    }
}

/// Annealed optimization beats constant-temperature sampling on MaxCut.
#[test]
fn annealing_beats_constant_beta() {
    let wl = workloads::wl_maxcut_optsicom();
    let run = |schedule| {
        let a = build_algo(AlgoKind::Pas, SamplerKind::Gumbel, wl.model.as_ref(), 8);
        let mut chain = Chain::new(wl.model.as_ref(), a, schedule, 0xAA);
        chain.run(400);
        chain.best_objective
    };
    let annealed = run(BetaSchedule::Linear {
        from: 0.2,
        to: 4.0,
        steps: 300,
    });
    let hot = run(BetaSchedule::Constant(0.2));
    assert!(
        annealed > hot,
        "annealed {annealed} should beat hot-only {hot}"
    );
}
