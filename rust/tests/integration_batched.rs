//! Scalar-vs-batched equivalence: for every registry workload, the
//! thread-per-chain `SoftwareBackend` and the `BatchedSoftwareBackend`
//! must produce **identical** chains (`best_x`, final energies,
//! marginals, traces) from the same seeds — for every batch size and
//! thread count. This pins down the bit-identity invariant the batched
//! execution path is built on.

use mc2a::engine::{registry, BatchedSoftwareBackend, Engine, Mc2aError};
use mc2a::mcmc::BetaSchedule;

const CHAINS: usize = 6;
const STEPS: usize = 8;
const SEED: u64 = 0xE0_1D;

fn run_workload(name: &str, batch: Option<(usize, usize)>) -> mc2a::coordinator::RunMetrics {
    let mut builder = Engine::for_workload(name)
        .unwrap()
        .schedule(BetaSchedule::Constant(0.9))
        .steps(STEPS)
        .chains(CHAINS)
        .seed(SEED)
        .observe_every(2);
    if let Some((k, t)) = batch {
        builder = builder.batch(k).threads(t);
    }
    builder
        .build()
        .unwrap_or_else(|e| panic!("{name}: {e}"))
        .run()
        .unwrap_or_else(|e| panic!("{name}: {e}"))
}

/// Every (non-heavy) registry workload: software == batched, chain by
/// chain, bit for bit — including the PAS workloads, which now run the
/// true batched PAS kernel (shared K-wide head-weight build, per-chain
/// path replay) rather than a scalar fallback.
#[test]
fn every_registry_workload_is_backend_invariant() {
    for entry in registry::REGISTRY {
        if entry.heavy {
            continue;
        }
        let scalar = run_workload(entry.name, None);
        let batched = run_workload(entry.name, Some((4, 3)));
        assert_eq!(scalar.chains.len(), batched.chains.len());
        for (a, b) in scalar.chains.iter().zip(&batched.chains) {
            assert_eq!(a.chain_id, b.chain_id, "{}", entry.name);
            assert_eq!(a.best_x, b.best_x, "{}: best_x diverges", entry.name);
            assert_eq!(
                a.best_objective, b.best_objective,
                "{}: best objective diverges",
                entry.name
            );
            assert_eq!(
                a.objective_trace, b.objective_trace,
                "{}: final energies diverge",
                entry.name
            );
            assert_eq!(a.marginal0, b.marginal0, "{}: marginals diverge", entry.name);
            assert_eq!(a.steps, b.steps, "{}", entry.name);
        }
    }
}

/// Chains must not depend on how the batch boundary falls or how many
/// workers the pool runs.
#[test]
fn results_are_invariant_to_batch_size_and_thread_count() {
    let reference = run_workload("imageseg", Some((1, 1)));
    for (k, t) in [(2, 1), (3, 2), (4, 4), (CHAINS, 2)] {
        let got = run_workload("imageseg", Some((k, t)));
        for (a, b) in reference.chains.iter().zip(&got.chains) {
            assert_eq!(a.best_x, b.best_x, "batch={k} threads={t}");
            assert_eq!(a.objective_trace, b.objective_trace, "batch={k} threads={t}");
            assert_eq!(a.marginal0, b.marginal0, "batch={k} threads={t}");
        }
    }
}

/// The batched backend reports its name and honors early stop through
/// the engine observer loop (per batch, at observation boundaries).
#[test]
fn batched_backend_early_stops() {
    use mc2a::engine::{ChainObserver, ObserverAction, ProgressEvent};
    struct StopImmediately;
    impl ChainObserver for StopImmediately {
        fn on_progress(&mut self, _e: &ProgressEvent) -> ObserverAction {
            ObserverAction::Stop
        }
    }
    let metrics = Engine::for_workload("imageseg")
        .unwrap()
        .steps(100_000)
        .chains(8)
        .batch(4)
        .threads(2)
        .observe_every(2)
        .observer(Box::new(StopImmediately))
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(
        metrics.chains.iter().any(|c| c.steps < 100_000),
        "no chain stopped early: {:?}",
        metrics.chains.iter().map(|c| c.steps).collect::<Vec<_>>()
    );
}

/// Typed validation for the new knobs.
#[test]
fn batch_and_thread_validation_is_typed() {
    let err = Engine::for_workload("earthquake")
        .unwrap()
        .chains(2)
        .batch(8)
        .build()
        .unwrap_err();
    match err {
        Mc2aError::InvalidConfig(msg) => {
            assert!(msg.contains("batch") && msg.contains("chains"), "{msg}")
        }
        e => panic!("wrong error: {e}"),
    }
    assert!(matches!(
        Engine::for_workload("earthquake").unwrap().batch(0).build(),
        Err(Mc2aError::InvalidConfig(_))
    ));
}

/// A custom wiring of the backend type through `.backend(...)` works
/// exactly like the builder's `.batch(...)` sugar.
#[test]
fn explicit_backend_box_matches_builder_sugar() {
    let via_sugar = run_workload("survey", Some((3, 2)));
    let via_box = Engine::for_workload("survey")
        .unwrap()
        .schedule(BetaSchedule::Constant(0.9))
        .steps(STEPS)
        .chains(CHAINS)
        .seed(SEED)
        .observe_every(2)
        .backend(Box::new(BatchedSoftwareBackend::new(3).with_threads(2)))
        .build()
        .unwrap()
        .run()
        .unwrap();
    for (a, b) in via_sugar.chains.iter().zip(&via_box.chains) {
        assert_eq!(a.best_x, b.best_x);
        assert_eq!(a.objective_trace, b.objective_trace);
    }
}
