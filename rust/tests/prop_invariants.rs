//! Property-based tests over randomized inputs.
//!
//! The offline vendor set has no `proptest`, so this file uses the
//! crate's own deterministic RNG as a generator: each property runs
//! against many random cases, and failures print the seed so the case
//! can be replayed. The invariants covered are the coordinator-level
//! ones the architecture depends on: routing (crossbar ranges, bank
//! conflicts), batching (parallel updates are independent sets; every
//! RV covered exactly once), and state management (histogram
//! conservation, sample-memory validity, ISA round-trip).

use mc2a::compiler::{compile, validate_program};
use mc2a::energy::{EnergyModel, MaxCutModel, MisModel, PottsGrid};
use mc2a::graph::{color_greedy, erdos_renyi_with_edges, Graph};
use mc2a::isa::{HwConfig, InstrLayout, Semantics};
use mc2a::mcmc::{build_algo, AlgoKind, BetaSchedule, Chain, SamplerKind};
use mc2a::rng::Rng;
use mc2a::sim::Simulator;

const CASES: usize = 25;

fn random_hw(rng: &mut Rng) -> HwConfig {
    let m = 2 + rng.below(5); // S ∈ {4..64}
    HwConfig {
        t: [4, 8, 16, 32, 64][rng.below(5)],
        k: 1 + rng.below(3),
        s: 1 << m,
        m,
        bw_words: [8, 32, 64, 320][rng.below(4)],
        clock_ghz: 0.5,
        rf_banks: [8, 16, 64][rng.below(3)],
        rf_regs_per_bank: 16,
        lut_size: 16,
        lut_bits: 8,
        max_dist_size: 256,
    }
}

fn random_model(rng: &mut Rng) -> Box<dyn EnergyModel> {
    match rng.below(3) {
        0 => {
            let h = 2 + rng.below(6);
            let w = 2 + rng.below(6);
            let labels = 2 + rng.below(3);
            Box::new(PottsGrid::new(h, w, labels, 0.5 + rng.uniform_f32()))
        }
        1 => {
            let n = 10 + rng.below(60);
            let max_m = n * (n - 1) / 2;
            let m = (n + rng.below(3 * n)).min(max_m);
            Box::new(MaxCutModel::new(
                erdos_renyi_with_edges(n, m, rng.next_u64()),
                None,
            ))
        }
        _ => {
            let n = 10 + rng.below(40);
            let max_m = n * (n - 1) / 2;
            let m = (n + rng.below(2 * n)).min(max_m);
            Box::new(MisModel::new(
                erdos_renyi_with_edges(n, m, rng.next_u64()),
                1.5,
                None,
            ))
        }
    }
}

/// Greedy coloring is always proper and within the degree bound.
#[test]
fn prop_coloring_proper() {
    let mut rng = Rng::new(0xC010);
    for case in 0..CASES {
        let n = 5 + rng.below(100);
        let max_m = n * (n - 1) / 2;
        let m = rng.below(max_m + 1);
        let g = erdos_renyi_with_edges(n, m, rng.next_u64());
        let c = color_greedy(&g);
        assert!(c.is_proper(&g), "case {case}: improper coloring");
        assert!(
            (c.num_colors as usize) <= g.max_degree() + 1,
            "case {case}: too many colors"
        );
        let total: usize = c.blocks().iter().map(|b| b.len()).sum();
        assert_eq!(total, n, "case {case}: blocks lose nodes");
    }
}

/// Every compiled program passes the full static validator, for random
/// models × random hardware × every algorithm.
#[test]
fn prop_compiled_programs_validate() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..CASES {
        let hw = random_hw(&mut rng);
        let model = random_model(&mut rng);
        for algo in [
            AlgoKind::Gibbs,
            AlgoKind::BlockGibbs,
            AlgoKind::AsyncGibbs,
            AlgoKind::Pas,
        ] {
            let p = compile(model.as_ref(), algo, &hw, 1 + rng.below(8)).unwrap();
            let coverage = !matches!(algo, AlgoKind::Pas);
            let v = validate_program(&p, model.as_ref(), &hw, coverage);
            assert!(
                v.is_empty(),
                "case {case} {algo:?} hw={hw:?}: {:?}",
                &v[..v.len().min(3)]
            );
        }
    }
}

/// ISA round-trip on real compiled programs for random configs.
#[test]
fn prop_isa_roundtrip() {
    let mut rng = Rng::new(0x150);
    for case in 0..CASES {
        let hw = random_hw(&mut rng);
        let layout = InstrLayout::new(&hw);
        let model = random_model(&mut rng);
        let algo = [AlgoKind::Gibbs, AlgoKind::BlockGibbs, AlgoKind::Pas][rng.below(3)];
        let p = compile(model.as_ref(), algo, &hw, 4).unwrap();
        let enc = layout.encode(&p.body);
        let dec = layout.decode(&enc).unwrap_or_else(|e| panic!("case {case}: {e}"));
        for (a, b) in p.body.iter().zip(&dec) {
            assert_eq!(a.loads, b.loads, "case {case}");
            assert_eq!(a.routes, b.routes, "case {case}");
        }
    }
}

/// Simulator state management: sample memory stays within each RV's
/// cardinality and histogram totals equal the iteration count.
#[test]
fn prop_sim_state_conserved() {
    let mut rng = Rng::new(0x57a7e);
    for case in 0..12 {
        let hw = random_hw(&mut rng);
        let model = random_model(&mut rng);
        let p = compile(model.as_ref(), AlgoKind::BlockGibbs, &hw, 1).unwrap();
        let mut sim = Simulator::new(hw, model.as_ref(), 1, rng.next_u64());
        let iters = 5 + rng.below(20);
        let rep = sim.run(&p, iters);
        assert_eq!(rep.iterations, iters as u64, "case {case}");
        assert_eq!(rep.updates, iters as u64 * model.num_vars() as u64);
        for i in 0..model.num_vars() {
            assert!(
                (sim.x[i] as usize) < model.num_states(i),
                "case {case}: rv {i} out of range"
            );
            let marg = sim.marginal(i);
            let total: f64 = marg.iter().sum();
            assert!((total - 1.0).abs() < 1e-9, "case {case}: marginal sum {total}");
        }
    }
}

/// β schedules on random configurations: every valid ramp moves
/// monotonically from `from` toward `to`, never overshoots in either
/// direction (the wrong-sided Geometric clamp regression), and
/// linear/geometric ramps terminate exactly at `to`.
#[test]
fn prop_beta_schedules_monotone_toward_to() {
    let mut rng = Rng::new(0xBE7A);
    for case in 0..CASES {
        let from = 0.05 + 4.0 * rng.uniform_f32();
        let to = 0.05 + 4.0 * rng.uniform_f32();
        let schedule = if rng.below(2) == 0 {
            BetaSchedule::Linear {
                from,
                to,
                steps: 1 + rng.below(60),
            }
        } else {
            // Rate pointed at the target: > 1 when heating, < 1 when
            // cooling (a valid configuration by construction).
            let rate = if to >= from {
                1.05 + rng.uniform_f32()
            } else {
                0.3 + 0.6 * rng.uniform_f32()
            };
            BetaSchedule::Geometric { from, to, rate }
        };
        schedule.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
        let (lo, hi) = (from.min(to), from.max(to));
        let mut prev = schedule.beta(0);
        assert_eq!(prev, from, "case {case} {schedule:?}: wrong start");
        for t in 1..400 {
            let b = schedule.beta(t);
            assert!(
                (lo..=hi).contains(&b),
                "case {case} {schedule:?}: β {b} outside [{lo}, {hi}] at t={t}"
            );
            if from <= to {
                assert!(b >= prev, "case {case} {schedule:?}: decreased at t={t}");
            } else {
                assert!(b <= prev, "case {case} {schedule:?}: increased at t={t}");
            }
            prev = b;
        }
        assert_eq!(
            schedule.beta(399),
            to,
            "case {case} {schedule:?}: never clamped to `to`"
        );
    }
}

/// Chain bookkeeping: best_objective is the max over the trajectory
/// and always achievable by the stored assignment.
#[test]
fn prop_chain_best_tracking() {
    let mut rng = Rng::new(0xBE57);
    for case in 0..CASES {
        let model = random_model(&mut rng);
        let algo_kind = [AlgoKind::Gibbs, AlgoKind::Mh, AlgoKind::Pas][rng.below(3)];
        let a = build_algo(algo_kind, SamplerKind::Gumbel, model.as_ref(), 2);
        let mut chain = Chain::new(model.as_ref(), a, BetaSchedule::Constant(1.0), rng.next_u64());
        chain.run(30);
        let recomputed = model.objective(chain.best_assignment());
        assert!(
            (chain.best_objective - recomputed).abs() < 1e-6,
            "case {case} {algo_kind:?}: stored {} vs recomputed {}",
            chain.best_objective,
            recomputed
        );
        assert!(
            chain.best_objective >= model.objective(&chain.x) - 1e-9,
            "case {case}: current beats best"
        );
    }
}

/// Energy-model consistency on random states: local_energies diffs ==
/// full-energy diffs (the contract every layer depends on).
#[test]
fn prop_local_energy_consistency() {
    let mut rng = Rng::new(0x10ca1);
    for case in 0..CASES {
        let model = random_model(&mut rng);
        let x: Vec<u32> = (0..model.num_vars())
            .map(|i| rng.below(model.num_states(i)) as u32)
            .collect();
        let base = model.energy(&x);
        let mut out = Vec::new();
        // spot-check 5 random vars
        for _ in 0..5 {
            let i = rng.below(model.num_vars());
            model.local_energies(&x, i, &mut out);
            let cur = out[x[i] as usize];
            let s = rng.below(model.num_states(i)) as u32;
            let mut y = x.clone();
            y[i] = s;
            let want = (model.energy(&y) - base) as f32;
            let got = out[s as usize] - cur;
            assert!(
                (want - got).abs() <= 1e-3 * (1.0 + want.abs()),
                "case {case} var {i} state {s}: {got} vs {want}"
            );
        }
    }
}

/// The static-analysis engine agrees with the compiler: random models ×
/// random hardware × every algorithm analyze with zero error-severity
/// findings (warnings/infos are allowed — AG programs report their
/// hazard window, dead stores are expected from the rotating RF
/// allocator).
#[test]
fn prop_analysis_clean_on_compiled_programs() {
    use mc2a::compiler::analysis;
    let mut rng = Rng::new(0xA11A);
    for case in 0..CASES {
        let hw = random_hw(&mut rng);
        let model = random_model(&mut rng);
        for algo in [
            AlgoKind::Mh,
            AlgoKind::Gibbs,
            AlgoKind::BlockGibbs,
            AlgoKind::AsyncGibbs,
            AlgoKind::Pas,
        ] {
            let p = compile(model.as_ref(), algo, &hw, 1 + rng.below(8)).unwrap();
            let r = analysis::analyze_program(
                &p,
                model.as_ref(),
                &hw,
                analysis::algo_expects_full_coverage(algo),
            );
            assert!(
                !r.has_errors(),
                "case {case} {algo:?} hw={hw:?}:\n{}",
                r.render_human()
            );
        }
    }
}

/// Chromatic analysis on random honest models: the greedy coloring is
/// blanket-independent both structurally and under functional probes.
#[test]
fn prop_chromatic_clean_on_random_models() {
    use mc2a::compiler::analysis;
    let mut rng = Rng::new(0xC0104);
    for case in 0..CASES {
        let model = random_model(&mut rng);
        let r = analysis::analyze_chromatic(model.as_ref());
        assert!(!r.has_errors(), "case {case}:\n{}", r.render_human());
    }
}

/// Ensemble analysis across the registry: every shardable workload ×
/// {BG, AG} × {2, 4} cores compiles into an ensemble with aligned
/// rounds, single-writer ownership, race-free synchronization rounds,
/// and no error-severity findings.
#[test]
fn prop_registry_ensembles_analyze_clean() {
    use mc2a::compiler::analysis;
    use mc2a::isa::MultiHwConfig;
    let hw = HwConfig::paper_default();
    for e in mc2a::engine::registry::REGISTRY {
        if e.heavy {
            continue;
        }
        let wl = e.build();
        let model = wl.model.as_ref();
        for algo in [AlgoKind::BlockGibbs, AlgoKind::AsyncGibbs] {
            for cores in [2usize, 4] {
                if mc2a::sim::multicore::validate_shard_config(model.num_vars(), algo, cores)
                    .is_err()
                {
                    continue;
                }
                let mhw = MultiHwConfig::new(hw, cores);
                let r = analysis::analyze_ensemble(model, algo, &mhw, wl.pas_flips.max(1))
                    .unwrap_or_else(|err| panic!("{} {algo:?} x{cores}: {err}", wl.name));
                assert!(
                    !r.has_errors(),
                    "{} {algo:?} x{cores}:\n{}",
                    wl.name,
                    r.render_human()
                );
            }
        }
        // Single-core sanity on the workload's native algorithm too.
        let p = compile(model, wl.algorithm, &hw, wl.pas_flips.max(1)).unwrap();
        let r = analysis::analyze_program(
            &p,
            model,
            &hw,
            analysis::algo_expects_full_coverage(wl.algorithm),
        );
        assert!(!r.has_errors(), "{}:\n{}", wl.name, r.render_human());
    }
}

/// Crossbar routing ranges hold even on adversarial dense graphs.
#[test]
fn prop_routes_in_range_dense_graph() {
    let mut rng = Rng::new(0xDE4);
    for _ in 0..10 {
        let n = 20 + rng.below(20);
        // near-complete graph: stress the neighbor-words path
        let mut edges = Vec::new();
        for a in 0..n as u32 {
            for b in (a + 1)..n as u32 {
                if rng.below(10) < 8 {
                    edges.push((a, b));
                }
            }
        }
        let g = Graph::from_edges(n, &edges, None);
        let m = MaxCutModel::new(g, None);
        let hw = random_hw(&mut rng);
        let p = compile(&m, AlgoKind::BlockGibbs, &hw, 1).unwrap();
        for i in p.prologue.iter().chain(&p.body) {
            for r in &i.routes {
                assert!((r.cu as usize) < hw.t);
                assert!((r.port as usize) < (1 << hw.k));
                assert!((r.rf_bank as usize) < hw.rf_banks);
            }
            if let Semantics::UpdateRvs(rvs) = &i.sem {
                assert!(rvs.len() <= hw.t.min(hw.s));
            }
        }
    }
}
