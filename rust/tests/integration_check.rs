//! Integration tests for the static-analysis subsystem
//! (`compiler::analysis`): the backend gates that reject invalid
//! programs with [`Mc2aError::InvalidProgram`] *before* simulation,
//! the registry-wide clean sweep the `check --all` acceptance bar
//! demands, and the `mc2a check` CLI verb end-to-end.

use std::process::Command;
use std::sync::atomic::AtomicBool;

use mc2a::compiler::{analysis, compile};
use mc2a::energy::PottsGrid;
use mc2a::engine::{
    AcceleratorBackend, ChainCtx, ChainSpec, ExecutionBackend, Mc2aError,
    MultiCoreAcceleratorBackend, REGISTRY,
};
use mc2a::isa::{HwConfig, Instr, MultiHwConfig, Program, Semantics};
use mc2a::mcmc::{AlgoKind, BetaSchedule, SamplerKind};

fn spec(algo: AlgoKind) -> ChainSpec {
    ChainSpec {
        algo,
        sampler: SamplerKind::Gumbel,
        schedule: BetaSchedule::Constant(1.0),
        beta_offset: 0,
        steps: 3,
        seed: 7,
        pas_flips: 2,
        observe_every: 0,
        init_state: None,
    }
}

/// Corrupt hook: point one crossbar route at a non-existent RF bank.
fn break_route(p: &mut Program) {
    for i in &mut p.body {
        if let Some(r) = i.routes.first_mut() {
            r.rf_bank = 9999;
            return;
        }
    }
    panic!("program has no routes to corrupt");
}

/// Corrupt hook: make every shard claim an update of RV 0, so all but
/// the owning core violate single-writer ownership.
fn inject_foreign_update(p: &mut Program) {
    let mut i = Instr::nop();
    i.sem = Semantics::UpdateRvs(vec![0]);
    p.body.push(i);
}

/// Every registry workload × algorithm × {1, 4} cores analyzes with
/// zero error-severity findings — the library-level `check --all` bar.
#[test]
fn registry_sweep_is_clean() {
    let hw = HwConfig::paper_default();
    for e in REGISTRY {
        if e.heavy {
            continue;
        }
        let wl = e.build();
        let model = wl.model.as_ref();
        let flips = wl.pas_flips.max(1);
        let chrom = analysis::analyze_chromatic(model);
        assert!(!chrom.has_errors(), "{} chromatic:\n{}", wl.name, chrom.render_human());
        for algo in [
            AlgoKind::Mh,
            AlgoKind::Gibbs,
            AlgoKind::BlockGibbs,
            AlgoKind::AsyncGibbs,
            AlgoKind::Pas,
        ] {
            let p = compile(model, algo, &hw, flips).unwrap();
            let r = analysis::analyze_program(
                &p,
                model,
                &hw,
                analysis::algo_expects_full_coverage(algo),
            );
            assert!(!r.has_errors(), "{} {algo:?} x1:\n{}", wl.name, r.render_human());
            if mc2a::sim::multicore::validate_shard_config(model.num_vars(), algo, 4).is_ok() {
                let mhw = MultiHwConfig::new(hw, 4);
                let r = analysis::analyze_ensemble(model, algo, &mhw, flips).unwrap();
                assert!(!r.has_errors(), "{} {algo:?} x4:\n{}", wl.name, r.render_human());
            }
        }
    }
}

/// The accelerator backend runs clean programs and rejects corrupted
/// ones with [`Mc2aError::InvalidProgram`] before simulation.
#[test]
fn accelerator_backend_gates_corrupted_program() {
    let model = PottsGrid::new(6, 6, 3, 1.0);
    let hw = HwConfig::paper_default();
    let stop = AtomicBool::new(false);
    let ctx = ChainCtx { stop: &stop, events: None, restart: None };

    let clean = AcceleratorBackend::new(hw);
    clean
        .run_chain(&model, &spec(AlgoKind::BlockGibbs), 0, &ctx)
        .expect("clean program must pass the gate and simulate");

    let bad = AcceleratorBackend::new(hw).with_corrupt_hook(break_route);
    match bad.run_chain(&model, &spec(AlgoKind::BlockGibbs), 0, &ctx) {
        Err(Mc2aError::InvalidProgram { diagnostics }) => {
            assert!(
                diagnostics
                    .iter()
                    .any(|d| d.code == analysis::DiagCode::RouteOutOfRange),
                "{diagnostics:?}"
            );
        }
        other => panic!("expected InvalidProgram, got {other:?}"),
    }
}

/// The multi-core backend gates the whole shard ensemble: a foreign
/// write injected into every shard trips the single-writer ownership
/// check before the multi-core simulator is even constructed.
#[test]
fn multicore_backend_gates_foreign_write() {
    let model = PottsGrid::new(8, 8, 3, 1.0);
    let hw = HwConfig::paper_default();
    let stop = AtomicBool::new(false);
    let ctx = ChainCtx { stop: &stop, events: None, restart: None };

    let clean = MultiCoreAcceleratorBackend::new(hw, 2);
    clean
        .run_chain(&model, &spec(AlgoKind::BlockGibbs), 0, &ctx)
        .expect("clean ensemble must pass the gate and simulate");

    let bad = MultiCoreAcceleratorBackend::new(hw, 4).with_corrupt_hook(inject_foreign_update);
    match bad.run_chain(&model, &spec(AlgoKind::BlockGibbs), 0, &ctx) {
        Err(Mc2aError::InvalidProgram { diagnostics }) => {
            assert!(
                diagnostics
                    .iter()
                    .any(|d| d.code == analysis::DiagCode::OwnershipViolation),
                "{diagnostics:?}"
            );
        }
        other => panic!("expected InvalidProgram, got {other:?}"),
    }
}

/// Invalid hardware is a typed error from the compile path (no panic),
/// for both the bare compiler and the backend.
#[test]
fn invalid_hardware_is_typed_error() {
    let model = PottsGrid::new(4, 4, 2, 1.0);
    let mut hw = HwConfig::paper_default();
    hw.s = 48; // not 2^m
    assert!(matches!(
        compile(&model, AlgoKind::Gibbs, &hw, 1),
        Err(Mc2aError::InvalidHardware(_))
    ));
    let stop = AtomicBool::new(false);
    let ctx = ChainCtx { stop: &stop, events: None, restart: None };
    assert!(matches!(
        AcceleratorBackend::new(hw).run_chain(&model, &spec(AlgoKind::Gibbs), 0, &ctx),
        Err(Mc2aError::InvalidHardware(_))
    ));
}

// ---- CLI end-to-end ---------------------------------------------------

fn mc2a_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_mc2a"))
}

#[test]
fn cli_check_single_workload_is_clean() {
    let out = mc2a_bin()
        .args(["check", "--workload", "earthquake"])
        .output()
        .expect("spawn mc2a");
    assert!(
        out.status.success(),
        "stdout:\n{}\nstderr:\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("0 error(s)"), "{stdout}");
}

#[test]
fn cli_check_all_json_is_clean_and_parses() {
    let out = mc2a_bin()
        .args(["check", "--all", "--format", "json"])
        .output()
        .expect("spawn mc2a");
    assert!(
        out.status.success(),
        "stderr:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout.trim();
    assert!(line.starts_with("{\"records\":[") && line.ends_with('}'), "{line}");
    assert!(line.contains("\"errors\":0"), "{line}");
    assert!(!line.contains("\"severity\":\"error\""), "{line}");
}

#[test]
fn cli_check_bad_hardware_exits_nonzero() {
    let out = mc2a_bin()
        .args(["check", "--workload", "earthquake", "--hw", "s=48"])
        .output()
        .expect("spawn mc2a");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("invalid hardware"), "{stderr}");
}

#[test]
fn cli_check_requires_a_target() {
    let out = mc2a_bin().args(["check"]).output().expect("spawn mc2a");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--workload") && stderr.contains("--all"), "{stderr}");
}

#[test]
fn cli_check_sampler_mismatch_warns_but_passes() {
    let out = mc2a_bin()
        .args(["check", "--workload", "earthquake", "--sampler", "lut:64:12", "--cores", "1"])
        .output()
        .expect("spawn mc2a");
    assert!(
        out.status.success(),
        "stderr:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("MC2A018"), "{stdout}");
}
