//! Integration tests for the unified `Engine` API: builder error
//! paths, workload-registry coverage, observer streaming + early-stop
//! semantics, convergence diagnostics, and backend pluggability.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use mc2a::coordinator::ChainResult;
use mc2a::energy::PottsGrid;
use mc2a::engine::{
    registry, ChainCtx, ChainSpec, ChainObserver, ConvergenceStop, DiagnosticsReport, Engine,
    ExecutionBackend, Mc2aError, ObserverAction, ProgressEvent,
};
use mc2a::mcmc::{AlgoKind, StepStats};

// ---------------------------------------------------------------- builder

#[test]
fn builder_rejects_zero_chains() {
    let m = PottsGrid::new(4, 4, 2, 0.5);
    match Engine::for_model(&m).chains(0).build() {
        Err(Mc2aError::InvalidConfig(msg)) => assert!(msg.contains("chains"), "{msg}"),
        Ok(_) => panic!("zero chains accepted"),
        Err(e) => panic!("wrong error: {e}"),
    }
}

#[test]
fn unknown_workload_lists_registry() {
    match Engine::for_workload("no-such-workload") {
        Err(Mc2aError::UnknownWorkload { name, known }) => {
            assert_eq!(name, "no-such-workload");
            assert!(known.contains(&"earthquake".to_string()));
            assert!(known.contains(&"optsicom".to_string()));
        }
        Err(e) => panic!("wrong error: {e}"),
        Ok(_) => panic!("bogus workload resolved"),
    }
}

#[test]
fn runtime_backend_without_artifacts_is_a_typed_error() {
    let result = Engine::for_workload("earthquake")
        .unwrap()
        .runtime("definitely/not/a/real/artifact/dir")
        .build();
    match result {
        Err(Mc2aError::RuntimeUnavailable(msg)) => {
            assert!(!msg.is_empty(), "empty runtime error message");
        }
        Err(e) => panic!("wrong error: {e}"),
        Ok(_) => panic!("runtime backend built without artifacts"),
    }
}

#[test]
fn workload_defaults_come_from_table1_pairing() {
    let engine = Engine::for_workload("optsicom").unwrap().build().unwrap();
    assert_eq!(engine.spec().algo, AlgoKind::Pas);
    assert_eq!(engine.spec().pas_flips, 8);
    assert_eq!(engine.workload_name(), Some("optsicom"));
    let engine = Engine::for_workload("earthquake").unwrap().build().unwrap();
    assert_eq!(engine.spec().algo, AlgoKind::BlockGibbs);
}

// ------------------------------------------------------------- registry

/// Every (non-heavy) registry workload must construct and survive a
/// 10-step run on the software backend with its Table I pairing.
#[test]
fn every_registry_workload_runs_ten_steps() {
    for entry in registry::REGISTRY {
        if entry.heavy {
            continue; // full-scale MRF: construction alone dominates CI time
        }
        let metrics = Engine::for_workload(entry.name)
            .unwrap_or_else(|e| panic!("{}: {e}", entry.name))
            .steps(10)
            .build()
            .unwrap_or_else(|e| panic!("{}: {e}", entry.name))
            .run()
            .unwrap_or_else(|e| panic!("{}: {e}", entry.name));
        assert_eq!(metrics.chains.len(), 1, "{}", entry.name);
        let c = &metrics.chains[0];
        assert_eq!(c.steps, 10, "{}", entry.name);
        assert!(c.stats.updates > 0, "{} made no updates", entry.name);
        assert!(!c.best_x.is_empty(), "{} has no assignment", entry.name);
    }
}

#[test]
fn aliases_resolve_to_same_workload() {
    let a = Engine::for_workload("er700").unwrap().build().unwrap();
    let b = Engine::for_workload("mis").unwrap().build().unwrap();
    assert_eq!(a.model().num_vars(), b.model().num_vars());
}

// ------------------------------------------------- observer / early stop

#[derive(Default)]
struct Recording {
    events: Vec<(usize, usize)>, // (chain_id, step)
    diagnostics: Vec<DiagnosticsReport>,
    chains_done: usize,
}

struct RecordingObserver(Arc<Mutex<Recording>>);

impl ChainObserver for RecordingObserver {
    fn on_progress(&mut self, e: &ProgressEvent) -> ObserverAction {
        self.0.lock().unwrap().events.push((e.chain_id, e.step));
        ObserverAction::Continue
    }
    fn on_diagnostics(&mut self, d: &DiagnosticsReport) -> ObserverAction {
        self.0.lock().unwrap().diagnostics.push(*d);
        ObserverAction::Continue
    }
    fn on_chain_done(&mut self, _r: &ChainResult) {
        self.0.lock().unwrap().chains_done += 1;
    }
}

#[test]
fn observer_streams_ordered_events_and_diagnostics() {
    let m = PottsGrid::new(5, 5, 2, 0.5);
    let rec = Arc::new(Mutex::new(Recording::default()));
    let metrics = Engine::for_model(&m)
        .steps(200)
        .chains(2)
        .observe_every(20)
        .observer(Box::new(RecordingObserver(rec.clone())))
        .build()
        .unwrap()
        .run()
        .unwrap();
    let rec = rec.lock().unwrap();
    // 2 chains × 10 observation points.
    assert_eq!(rec.events.len(), 20, "{:?}", rec.events);
    for chain in 0..2 {
        let steps: Vec<usize> = rec
            .events
            .iter()
            .filter(|(c, _)| *c == chain)
            .map(|(_, s)| *s)
            .collect();
        assert_eq!(steps, (1..=10).map(|k| k * 20).collect::<Vec<_>>());
    }
    // One diagnostics report per completed round; R-hat defined from
    // round 4 (two split halves of ≥ 2 observations each).
    assert_eq!(rec.diagnostics.len(), 10);
    assert!(rec.diagnostics[0].r_hat.is_none());
    assert!(rec.diagnostics[9].r_hat.is_some());
    assert!(rec.diagnostics.iter().all(|d| d.min_ess >= 1.0));
    assert_eq!(rec.chains_done, 2);
    // The engine-level aggregate agrees with the streamed trace length.
    for c in &metrics.chains {
        assert_eq!(c.objective_trace.len(), 10);
    }
    assert!(metrics.split_r_hat().is_some());
}

struct StopAfter {
    seen: usize,
    limit: usize,
}

impl ChainObserver for StopAfter {
    fn on_progress(&mut self, _e: &ProgressEvent) -> ObserverAction {
        self.seen += 1;
        if self.seen >= self.limit {
            ObserverAction::Stop
        } else {
            ObserverAction::Continue
        }
    }
}

#[test]
fn early_stop_truncates_chains() {
    let m = PottsGrid::new(8, 8, 2, 0.5);
    let metrics = Engine::for_model(&m)
        .steps(50_000)
        .chains(2)
        .observe_every(10)
        .observer(Box::new(StopAfter { seen: 0, limit: 3 }))
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(
        metrics.chains.iter().any(|c| c.steps < 50_000),
        "no chain stopped early: {:?}",
        metrics.chains.iter().map(|c| c.steps).collect::<Vec<_>>()
    );
}

#[test]
fn convergence_stop_ends_mixed_chains_early() {
    // A tiny symmetric grid mixes almost immediately, so the R-hat
    // criterion must fire long before the 50k-step budget.
    let m = PottsGrid::new(4, 4, 2, 0.3);
    let metrics = Engine::for_model(&m)
        .steps(50_000)
        .chains(4)
        .observe_every(25)
        .observer(Box::new(ConvergenceStop {
            r_hat_target: 1.2,
            min_rounds: 4,
        }))
        .build()
        .unwrap()
        .run()
        .unwrap();
    for c in &metrics.chains {
        assert!(c.steps < 50_000, "chain {} never stopped", c.chain_id);
    }
}

// ------------------------------------------------------ custom backends

struct CountingBackend {
    calls: AtomicUsize,
}

impl ExecutionBackend for CountingBackend {
    fn name(&self) -> &'static str {
        "counting"
    }

    fn run_chain(
        &self,
        model: &dyn mc2a::energy::EnergyModel,
        spec: &ChainSpec,
        chain_id: usize,
        _ctx: &ChainCtx<'_>,
    ) -> Result<ChainResult, Mc2aError> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        Ok(ChainResult {
            chain_id,
            best_objective: 0.0,
            steps: spec.steps,
            stats: StepStats::default(),
            sim: None,
            multicore: None,
            tempering: None,
            wall: Duration::from_millis(1),
            marginal0: vec![1.0],
            best_x: vec![0; model.num_vars()],
            objective_trace: Vec::new(),
        })
    }
}

#[test]
fn custom_backends_plug_in_without_touching_call_sites() {
    let m = PottsGrid::new(3, 3, 2, 0.5);
    let mut engine = Engine::for_model(&m)
        .steps(7)
        .chains(3)
        .backend(Box::new(CountingBackend {
            calls: AtomicUsize::new(0),
        }))
        .build()
        .unwrap();
    assert_eq!(engine.backend_name(), "counting");
    let metrics = engine.run().unwrap();
    assert_eq!(metrics.chains.len(), 3);
    assert!(metrics.chains.iter().all(|c| c.steps == 7));
}

struct FailingBackend;

impl ExecutionBackend for FailingBackend {
    fn name(&self) -> &'static str {
        "failing"
    }

    fn run_chain(
        &self,
        _model: &dyn mc2a::energy::EnergyModel,
        _spec: &ChainSpec,
        chain_id: usize,
        _ctx: &ChainCtx<'_>,
    ) -> Result<ChainResult, Mc2aError> {
        Err(Mc2aError::Runtime(format!("chain {chain_id} boom")))
    }
}

#[test]
fn backend_errors_surface_as_results_not_panics() {
    let m = PottsGrid::new(3, 3, 2, 0.5);
    let err = Engine::for_model(&m)
        .chains(2)
        .backend(Box::new(FailingBackend))
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    assert!(matches!(err, Mc2aError::Runtime(_)), "{err}");
}

// -------------------------------------------------- accelerator parity

/// The accelerator backend must anneal: with a schedule that freezes
/// cold at the end, a run through the engine ends far more ordered
/// than a constant hot run — this regression-tests the old midpoint-β
/// bug, which made annealed sim runs equivalent to a constant lukewarm β.
#[test]
fn accelerator_backend_steps_the_beta_schedule() {
    use mc2a::isa::HwConfig;
    use mc2a::mcmc::BetaSchedule;
    let m = PottsGrid::new(8, 8, 2, 1.0);
    let run = |schedule| {
        let metrics = Engine::for_model(&m)
            .algo(AlgoKind::BlockGibbs)
            .schedule(schedule)
            .steps(300)
            .seed(0xC01D)
            .accelerator(HwConfig::fig10_toy())
            .build()
            .unwrap()
            .run()
            .unwrap();
        metrics.chains[0].best_objective
    };
    let annealed = run(BetaSchedule::Linear {
        from: 0.05,
        to: 4.0,
        steps: 200,
    });
    let hot = run(BetaSchedule::Constant(0.05));
    // Ferromagnet objective = -E; the annealed run must find a much
    // better (ordered) state than the permanently hot run.
    assert!(
        annealed > hot + 10.0,
        "annealed {annealed} vs hot {hot}: schedule not applied"
    );
}
