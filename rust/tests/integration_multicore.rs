//! Integration tests for the sharded multi-core MC²A simulation:
//! (a) a 1-core multi-core system is bit-identical to the single-core
//! accelerator backend on every (non-heavy) registry workload, (b)
//! C > 1 produces statistically correct samples (exact Bayes-net
//! posterior, Ising phase behavior) with the same tolerances
//! `integration_sim.rs` uses, (c) adding cores cuts the synchronized
//! makespan, and (d) checkpoints round-trip through the builder's
//! `init_state`.

use mc2a::energy::PottsGrid;
use mc2a::engine::{registry, Checkpoint, Engine, EngineBuilder};
use mc2a::isa::{HwConfig, MultiHwConfig};
use mc2a::mcmc::AlgoKind;
use mc2a::sim::MultiCoreSim;
use mc2a::workloads;

/// THE C=1 equivalence test: same seeds, same programs, same cycles,
/// same samples as the single-core `AcceleratorBackend` — for every
/// registry workload, including the PAS-paired COP suite.
#[test]
fn one_core_backend_is_bit_identical_to_accelerator_everywhere() {
    for entry in registry::REGISTRY.iter().filter(|e| !e.heavy) {
        let run = |multi: bool| {
            let mut b = Engine::for_workload(entry.name).unwrap().steps(6).seed(0xF00D);
            b = if multi {
                b.multicore(HwConfig::paper_default()).cores(1)
            } else {
                b.accelerator(HwConfig::paper_default())
            };
            b.build().unwrap().run().unwrap()
        };
        let single = run(false);
        let multi = run(true);
        let (a, b) = (&single.chains[0], &multi.chains[0]);
        assert_eq!(a.best_x, b.best_x, "{}: state diverged", entry.name);
        assert_eq!(a.best_objective, b.best_objective, "{}", entry.name);
        assert_eq!(a.marginal0, b.marginal0, "{}", entry.name);
        assert_eq!(a.objective_trace, b.objective_trace, "{}", entry.name);
        assert_eq!(a.steps, b.steps, "{}", entry.name);
        let (ra, rb) = (a.sim.as_ref().unwrap(), b.sim.as_ref().unwrap());
        assert_eq!(ra.cycles, rb.cycles, "{}: cycle count diverged", entry.name);
        assert_eq!(ra.instrs, rb.instrs, "{}", entry.name);
        assert_eq!(ra.nops, rb.nops, "{}", entry.name);
        assert_eq!(ra.samples, rb.samples, "{}", entry.name);
        assert_eq!(ra.updates, rb.updates, "{}", entry.name);
        assert_eq!(ra.stall_mem_bw, rb.stall_mem_bw, "{}", entry.name);
        assert_eq!(ra.stall_bank, rb.stall_bank, "{}", entry.name);
        assert_eq!(ra.load_words, rb.load_words, "{}", entry.name);
        assert_eq!(ra.store_words, rb.store_words, "{}", entry.name);
        assert_eq!(rb.stall_sync, 0, "{}: phantom sync stalls", entry.name);
        assert_eq!(rb.stall_xbar, 0, "{}: phantom crossbar stalls", entry.name);
        assert_eq!(
            ra.energy.total_pj(),
            rb.energy.total_pj(),
            "{}: energy diverged",
            entry.name
        );
        let mc = b.multicore.as_ref().expect("multicore report");
        assert_eq!(mc.cores(), 1);
        assert_eq!(mc.xfer_words, 0);
    }
}

/// Sharded sampling stays correct: the 2-core accelerator posterior on
/// the earthquake net matches the exact marginal within the tolerance
/// `integration_sim.rs` uses for the single-core simulator.
#[test]
fn two_core_marginals_match_exact_posterior() {
    let net = workloads::earthquake();
    let exact = net.exact_marginal(2);
    let mhw = MultiHwConfig::new(HwConfig::paper_default(), 2);
    let mut sim = MultiCoreSim::new(mhw, &net, AlgoKind::BlockGibbs, 1, 0x51B).unwrap();
    let _ = sim.run(120_000);
    let marg = sim.marginal(2);
    assert!(
        (marg[1] - exact[1]).abs() < 0.02,
        "2-core accelerator {} vs exact {}",
        marg[1],
        exact[1]
    );
}

/// Ising phase behavior survives sharding: a cold 4-core chain keeps
/// its magnetization (the `sim_ising_orders_when_cold` story).
#[test]
fn four_core_ising_orders_when_cold() {
    let m = PottsGrid::new(16, 16, 2, 1.0);
    let mhw = MultiHwConfig::new(HwConfig::paper_default(), 4);
    let mut sim = MultiCoreSim::new(mhw, &m, AlgoKind::BlockGibbs, 1, 0xC01D).unwrap();
    sim.set_beta(2.0);
    let all_up = vec![1u32; 256];
    sim.set_state(&all_up);
    let _ = sim.run(300);
    let ones = sim.x.iter().filter(|&&v| v == 1).count();
    assert!(ones > 230, "magnetization lost: {ones}/256");
}

/// Scaling sanity through the engine: more cores must cut the
/// synchronized makespan on a parallel-friendly grid, and the report
/// must account interconnect traffic.
#[test]
fn more_cores_cut_cycles_through_the_backend() {
    let m = PottsGrid::new(32, 32, 2, 0.8);
    let cycles = |cores: usize| {
        let metrics = Engine::for_model(&m)
            .steps(5)
            .seed(9)
            .multicore(HwConfig::paper_default())
            .cores(cores)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let mc = metrics.chains[0].multicore.clone().expect("multicore report");
        (mc.cycles, mc.xfer_words, mc.sync_overhead_fraction())
    };
    let (c1, x1, _) = cycles(1);
    let (c8, x8, overhead8) = cycles(8);
    assert!(c8 < c1 / 2, "8-core {c8} vs 1-core {c1}");
    assert_eq!(x1, 0);
    assert!(x8 > 0);
    assert!(overhead8 > 0.0 && overhead8 < 0.9, "overhead {overhead8}");
}

/// Checkpoint → builder `init_state` round trip: resuming from a saved
/// best state starts the next run at (at least) that objective.
#[test]
fn checkpoint_resumes_through_init_state() {
    let m = PottsGrid::new(8, 8, 2, 1.0);
    let first = Engine::for_model(&m)
        .steps(50)
        .seed(3)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let best = &first.chains[0];
    let ck = Checkpoint {
        seed: 3,
        steps: best.steps,
        best_objective: best.best_objective,
        best_x: best.best_x.clone(),
        anneal: None,
        temper: None,
        workload: None,
        sampler: None,
        chains: None,
    };
    let path = std::env::temp_dir().join("mc2a_integration_checkpoint.json");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, ck);

    let resumed = Engine::for_model(&m)
        .steps(10)
        .seed(4)
        .init_state(loaded.best_x)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(
        resumed.chains[0].best_objective >= ck.best_objective,
        "resume lost ground: {} < {}",
        resumed.chains[0].best_objective,
        ck.best_objective
    );
}

/// The builder surfaces unshardable configurations as typed errors
/// before anything runs.
#[test]
fn builder_rejects_unshardable_multicore_runs() {
    fn build(b: EngineBuilder<'_>) -> bool {
        b.build().is_ok()
    }
    let m = PottsGrid::new(4, 4, 2, 0.5);
    assert!(!build(Engine::for_model(&m).algo(AlgoKind::Pas).cores(2)));
    assert!(!build(Engine::for_model(&m).algo(AlgoKind::Gibbs).cores(2)));
    assert!(build(Engine::for_model(&m).algo(AlgoKind::Pas).cores(1)));
    assert!(build(Engine::for_model(&m).algo(AlgoKind::AsyncGibbs).cores(2)));
    assert!(build(Engine::for_model(&m).cores(4))); // Block Gibbs default
}
