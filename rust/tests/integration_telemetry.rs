//! Integration tests for `engine::telemetry`: the metrics registry and
//! span tracer are observation-only, so enabling them must not change
//! a single bit of any chain result — and the Prometheus / Chrome
//! trace-event renderings they produce must be well-formed.

use std::sync::{Mutex, MutexGuard};

use mc2a::coordinator::RunMetrics;
use mc2a::engine::{profile, telemetry, Engine};
use mc2a::isa::HwConfig;

/// The registry and tracer are process-wide; serialize every test in
/// this binary that flips or reads their state.
static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restore the process-wide off-by-default state, even if a test
/// assertion fails midway.
struct TelemetryOff;

impl Drop for TelemetryOff {
    fn drop(&mut self) {
        telemetry::metrics().set_enabled(false);
        telemetry::metrics().reset();
        let t = telemetry::tracer();
        t.stop();
        t.start();
        t.stop(); // start+stop clears any events the test left behind
    }
}

/// Restore the off-by-default profiler state on exit.
struct ProfileOff;

impl Drop for ProfileOff {
    fn drop(&mut self) {
        profile::set_enabled(false);
    }
}

fn run_workload(workload: &str, batched: bool) -> RunMetrics {
    let mut builder = Engine::for_workload(workload)
        .expect(workload)
        .steps(20)
        .chains(4)
        .seed(0xBEEF);
    if batched {
        builder = builder.batch(2).threads(2);
    }
    builder.build().expect(workload).run().expect(workload)
}

/// Field-by-field bit comparison of two runs (floats via `to_bits`, so
/// NaN-safe and sensitive to sign/rounding differences `==` would hide).
fn assert_bit_identical(a: &RunMetrics, b: &RunMetrics, ctx: &str) {
    assert_eq!(a.chains.len(), b.chains.len(), "{ctx}: chain count");
    for (x, y) in a.chains.iter().zip(&b.chains) {
        let id = x.chain_id;
        assert_eq!(x.chain_id, y.chain_id, "{ctx}: chain id");
        assert_eq!(x.steps, y.steps, "{ctx} chain {id}: steps");
        assert_eq!(
            x.best_objective.to_bits(),
            y.best_objective.to_bits(),
            "{ctx} chain {id}: best objective"
        );
        assert_eq!(x.stats.updates, y.stats.updates, "{ctx} chain {id}: updates");
        assert_eq!(x.stats.accepted, y.stats.accepted, "{ctx} chain {id}: accepted");
        assert_eq!(x.stats.cost.ops, y.stats.cost.ops, "{ctx} chain {id}: ops");
        assert_eq!(x.stats.cost.bytes, y.stats.cost.bytes, "{ctx} chain {id}: bytes");
        assert_eq!(x.stats.cost.samples, y.stats.cost.samples, "{ctx} chain {id}: samples");
        assert_eq!(x.best_x, y.best_x, "{ctx} chain {id}: best assignment");
        let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&x.marginal0), bits(&y.marginal0), "{ctx} chain {id}: marginal0");
        assert_eq!(
            bits(&x.objective_trace),
            bits(&y.objective_trace),
            "{ctx} chain {id}: objective trace"
        );
    }
}

/// One run of `earthquake` on each execution backend the profiler
/// covers (software, batched, single-core sim, multi-core sim).
fn run_backend(backend: &str) -> RunMetrics {
    let mut builder = Engine::for_workload("earthquake")
        .expect(backend)
        .steps(20)
        .chains(4)
        .seed(0xBEEF);
    builder = match backend {
        "software" => builder.software(),
        "batched" => builder.batched().batch(2).threads(2),
        "sim" => builder.accelerator(HwConfig::paper_default()),
        "multicore" => builder.multicore(HwConfig::paper_default()).cores(2),
        other => panic!("unknown backend {other}"),
    };
    builder.build().expect(backend).run().expect(backend)
}

#[test]
fn enabling_profiling_does_not_change_any_result_bit() {
    let _g = guard();
    let _off = ProfileOff;
    for backend in ["software", "batched", "sim", "multicore"] {
        profile::set_enabled(false);
        let baseline = run_backend(backend);
        profile::set_enabled(true);
        let profiled = run_backend(backend);
        profile::set_enabled(false);
        assert_bit_identical(&baseline, &profiled, &format!("profile {backend}"));
    }
}

#[test]
fn profiled_run_yields_an_observation_per_backend() {
    let _g = guard();
    let _off = ProfileOff;
    profile::set_enabled(true);
    for backend in ["software", "batched", "sim", "multicore"] {
        let mut builder = Engine::for_workload("earthquake")
            .expect(backend)
            .steps(20)
            .chains(4)
            .seed(0xBEEF);
        builder = match backend {
            "software" => builder.software(),
            "batched" => builder.batched().batch(2).threads(2),
            "sim" => builder.accelerator(HwConfig::paper_default()),
            "multicore" => builder.multicore(HwConfig::paper_default()).cores(2),
            other => panic!("unknown backend {other}"),
        };
        let mut engine = builder.build().expect(backend);
        engine.run().expect(backend);
        let obs = engine.observation().unwrap_or_else(|| panic!("{backend}: no observation"));
        assert!(obs.samples > 0, "{backend}: no samples counted");
        assert!(
            obs.measured_gsps.is_finite() && obs.measured_gsps > 0.0,
            "{backend}: measured throughput"
        );
        assert!(
            obs.drift.predicted_gsps > 0.0,
            "{backend}: predicted roofline throughput"
        );
        // Simulated backends measure in the cycle domain; wall-clock
        // backends project through the measured intensities instead.
        let cycle = backend == "sim" || backend == "multicore";
        assert_eq!(obs.cycle_domain, cycle, "{backend}: domain");
        if cycle {
            // The roofline is an upper bound: a cycle-accurate run can
            // not beat it (small tolerance for rounding).
            assert!(
                obs.measured_gsps <= obs.drift.predicted_gsps * 1.05,
                "{backend}: measured {} exceeds roof {}",
                obs.measured_gsps,
                obs.drift.predicted_gsps
            );
        }
        let json = obs.to_json();
        assert!(json.contains("\"workload\":\"earthquake\""), "{backend}: {json}");
        assert!(json.contains("\"verdict\":"), "{backend}: {json}");
    }
}

#[test]
fn enabling_telemetry_does_not_change_any_result_bit() {
    let _g = guard();
    let _off = TelemetryOff;
    for workload in ["optsicom", "earthquake"] {
        for batched in [false, true] {
            let ctx = format!("{workload} batched={batched}");
            telemetry::metrics().set_enabled(false);
            telemetry::tracer().stop();
            let baseline = run_workload(workload, batched);
            telemetry::metrics().set_enabled(true);
            telemetry::tracer().start();
            let instrumented = run_workload(workload, batched);
            telemetry::tracer().stop();
            telemetry::metrics().set_enabled(false);
            assert_bit_identical(&baseline, &instrumented, &ctx);
        }
    }
}

#[test]
fn enabled_run_populates_chain_counters_and_prometheus_output() {
    let _g = guard();
    let _off = TelemetryOff;
    let reg = telemetry::metrics();
    reg.set_enabled(true);
    reg.reset();
    let metrics = run_workload("optsicom", false);
    reg.set_enabled(false);
    let chains = metrics.chains.len() as u64;
    assert_eq!(reg.counter_sum("chains_completed_total"), chains);
    let updates: u64 = metrics.chains.iter().map(|c| c.stats.updates).sum();
    assert_eq!(reg.counter_sum("chain_updates_total"), updates);
    let draws: u64 = metrics.chains.iter().map(|c| c.stats.cost.samples).sum();
    assert_eq!(reg.counter_sum("sampler_draws_total"), draws);
    let text = reg.render_prometheus();
    assert!(text.contains("# TYPE mc2a_chains_completed_total counter"), "{text}");
    assert!(text.contains("# TYPE mc2a_chain_updates_total counter"), "{text}");
    assert!(text.contains("backend="), "{text}");
}

#[test]
fn traced_run_emits_loadable_chrome_trace_json() {
    let _g = guard();
    let _off = TelemetryOff;
    let t = telemetry::tracer();
    t.start();
    run_workload("optsicom", true);
    t.stop();
    assert!(t.event_count() > 0, "no spans recorded");
    let json = t.to_chrome_json();
    assert!(json.trim_start().starts_with('['), "{json}");
    assert!(json.trim_end().ends_with(']'), "{json}");
    assert!(json.contains("\"ph\":\"X\""), "{json}");
    assert!(json.contains("\"cat\":\"engine\""), "{json}");
    assert!(json.contains("\"cat\":\"batched\""), "{json}");
    let path = std::env::temp_dir().join(format!("mc2a_trace_{}.json", std::process::id()));
    t.write(&path).expect("writing trace file");
    let on_disk = std::fs::read_to_string(&path).expect("reading trace file back");
    assert_eq!(on_disk, json);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn disabled_telemetry_records_nothing_during_a_run() {
    let _g = guard();
    let _off = TelemetryOff;
    let reg = telemetry::metrics();
    reg.set_enabled(false);
    reg.reset();
    telemetry::tracer().stop();
    run_workload("optsicom", false);
    assert!(!telemetry::enabled());
    assert_eq!(reg.counter_sum("chains_completed_total"), 0);
    assert_eq!(reg.render_prometheus(), "");
    assert_eq!(telemetry::tracer().event_count(), 0);
}
