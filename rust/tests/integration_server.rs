//! Integration tests for the multi-tenant job server (`engine::server`):
//! (a) concurrent heterogeneous jobs are bit-identical to solo
//! `Engine::run` calls with the same spec, (b) a high-priority job
//! overtakes an earlier low-priority queue, (c) cancel stops a huge job
//! promptly, (d) a killed server recovers from its job directory and
//! finishes interrupted jobs to the same bit-identical result, (e) the
//! newline-JSON TCP front-end round-trips submit/result/cancel, and
//! (f) `init_from_checkpoint` rejects mismatched resume attempts with
//! the typed error naming both sides.

use std::net::TcpListener;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use mc2a::coordinator::ChainResult;
use mc2a::engine::server::{net, proto};
use mc2a::engine::{
    Checkpoint, Engine, JobServer, JobServerConfig, JobSpec, JobState, Mc2aError, Priority,
    ServeBackend,
};
use mc2a::isa::HwConfig;

fn spec(workload: &str, steps: usize, chains: usize, seed: u64) -> JobSpec {
    let mut s = JobSpec::new(workload);
    s.steps = steps;
    s.chains = chains;
    s.seed = seed;
    s
}

/// The same run, solo, through the public engine builder.
fn solo(workload: &str, steps: usize, chains: usize, seed: u64, accel: bool) -> Vec<ChainResult> {
    let mut b = Engine::for_workload(workload)
        .unwrap()
        .steps(steps)
        .chains(chains)
        .seed(seed);
    if accel {
        b = b.accelerator(HwConfig::paper_default());
    }
    b.build().unwrap().run().unwrap().chains
}

fn assert_chains_match(label: &str, got: &[ChainResult], want: &[ChainResult]) {
    assert_eq!(got.len(), want.len(), "{label}: chain count");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.chain_id, w.chain_id, "{label}: chain id order");
        assert_eq!(g.best_x, w.best_x, "{label} chain {}: state diverged", w.chain_id);
        assert_eq!(g.best_objective, w.best_objective, "{label} chain {}", w.chain_id);
        assert_eq!(g.marginal0, w.marginal0, "{label} chain {}", w.chain_id);
        assert_eq!(g.objective_trace, w.objective_trace, "{label} chain {}", w.chain_id);
        assert_eq!(g.steps, w.steps, "{label} chain {}", w.chain_id);
    }
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mc2a_server_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// THE acceptance test: three heterogeneous jobs (COP / Potts-MRF /
/// Bayesian network, software and accelerator backends) sharing one
/// pool produce exactly the results their solo engine runs produce.
#[test]
fn concurrent_heterogeneous_jobs_match_solo_runs() {
    let server = JobServer::in_memory(2);
    let mut accel_spec = spec("earthquake", 200, 2, 3);
    accel_spec.backend = ServeBackend::Accelerator;
    let jobs = [
        ("optsicom", server.submit(spec("optsicom", 60, 2, 7)).unwrap(), false, 60, 7),
        ("imageseg", server.submit(spec("imageseg", 8, 2, 9)).unwrap(), false, 8, 9),
        ("earthquake", server.submit(accel_spec).unwrap(), true, 200, 3),
    ];
    for (workload, id, accel, steps, seed) in jobs {
        let result = server.wait(id, Duration::from_secs(300)).unwrap();
        assert_eq!(result.state, JobState::Done, "{workload}: {:?}", result.error);
        let want = solo(workload, steps, 2, seed, accel);
        assert_chains_match(workload, &result.chains, &want);
        let status = server.status(id).unwrap();
        assert_eq!(status.chains_done, 2, "{workload}");
        assert_eq!(status.steps_done, 2 * steps, "{workload}");
    }
    server.shutdown();
}

/// Strict priority: with one worker thread, a later high-priority job
/// finishes before an earlier low-priority one gets a slot.
#[test]
fn high_priority_job_overtakes_low_priority_queue() {
    let server = JobServer::in_memory(1);
    // Occupies the only thread while the queue forms behind it.
    let blocker = server.submit(spec("imageseg", 40, 1, 1)).unwrap();
    let mut low = spec("imageseg", 10, 2, 2);
    low.priority = Priority::Low;
    let low = server.submit(low).unwrap();
    let mut high = spec("optsicom", 5, 1, 3);
    high.priority = Priority::High;
    let high = server.submit(high).unwrap();
    let result = server.wait(high, Duration::from_secs(300)).unwrap();
    assert_eq!(result.state, JobState::Done);
    let low_status = server.status(low).unwrap();
    assert_ne!(
        low_status.state,
        JobState::Done,
        "low-priority job must not finish before the high-priority one"
    );
    assert!(low_status.chains_done < 2, "low job ran ahead of the high job");
    assert_eq!(server.wait(low, Duration::from_secs(300)).unwrap().state, JobState::Done);
    assert_eq!(server.wait(blocker, Duration::from_secs(300)).unwrap().state, JobState::Done);
    server.shutdown();
}

/// Cancel raises the per-job stop flag and the job goes terminal long
/// before its (deliberately enormous) step budget could complete.
#[test]
fn cancel_stops_a_running_job_promptly() {
    let server = JobServer::in_memory(2);
    let mut huge = spec("imageseg", 1_000_000, 2, 5);
    huge.observe_every = 1;
    let id = server.submit(huge).unwrap();
    let polling = Instant::now();
    loop {
        let s = server.status(id).unwrap();
        if s.state == JobState::Running || s.steps_done > 0 {
            break;
        }
        assert!(polling.elapsed() < Duration::from_secs(60), "job never started");
        std::thread::sleep(Duration::from_millis(5));
    }
    let cancelled_at = Instant::now();
    assert_eq!(server.cancel(id).unwrap(), JobState::Cancelled);
    let result = server.wait(id, Duration::from_secs(60)).unwrap();
    assert_eq!(result.state, JobState::Cancelled);
    assert!(
        cancelled_at.elapsed() < Duration::from_secs(60),
        "cancel should not wait for the step budget"
    );
    // Cancelling a terminal job is a no-op, not an error.
    assert_eq!(server.cancel(id).unwrap(), JobState::Cancelled);
    server.shutdown();
}

/// Durability: shut the server down mid-job (as a stand-in for a
/// crash after the last fsync), recover from the directory, and the
/// job finishes to the same bits a never-interrupted run produces.
#[test]
fn shutdown_then_recover_finishes_the_job_bit_identically() {
    let dir = fresh_dir("recover");
    let server = JobServer::new(JobServerConfig { threads: 1, dir: Some(dir.clone()) }).unwrap();
    // "maxcut" is an optsicom alias; the server canonicalizes it.
    let id = server.submit(spec("maxcut", 100, 3, 11)).unwrap();
    let polling = Instant::now();
    loop {
        let s = server.status(id).unwrap();
        if s.chains_done >= 1 {
            break;
        }
        assert!(polling.elapsed() < Duration::from_secs(120), "no chain finished");
        std::thread::sleep(Duration::from_millis(2));
    }
    server.shutdown();
    drop(server);

    let revived = JobServer::recover(&dir).unwrap();
    let status = revived.status(id).unwrap();
    assert_eq!(status.workload, "optsicom", "alias canonicalized in the envelope");
    let result = revived.wait(id, Duration::from_secs(300)).unwrap();
    assert_eq!(result.state, JobState::Done, "{:?}", result.error);
    assert_chains_match("recovered maxcut", &result.chains, &solo("optsicom", 100, 3, 11, false));
    // New submissions continue past the recovered id space.
    let next = revived.submit(spec("earthquake", 10, 1, 1)).unwrap();
    assert!(next > id);
    revived.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The TCP front-end: submit over loopback, poll `result` until done,
/// exercise the typed unknown-job error, then shut the daemon down.
#[test]
fn tcp_submit_poll_result_round_trip() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = JobServer::in_memory(2);
    let daemon = std::thread::spawn(move || net::serve_on(server, listener));

    let submitted =
        net::client_request(&addr, &proto::submit_line(&spec("optsicom", 30, 1, 5)), 4).unwrap();
    assert!(proto::response_is_ok(&submitted), "{submitted}");
    let id = proto::response_job(&submitted).expect("submit response carries the job id");

    let polling = Instant::now();
    let result = loop {
        let line = net::client_request(&addr, &proto::result_line(id), 0).unwrap();
        if proto::response_kind(&line).as_deref() != Some("not-finished") {
            break line;
        }
        assert!(polling.elapsed() < Duration::from_secs(120), "job never finished");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert!(proto::response_is_ok(&result), "{result}");
    assert!(result.contains("\"state\":\"done\""), "{result}");

    let missing = net::client_request(&addr, &proto::cancel_line(9999), 0).unwrap();
    assert_eq!(proto::response_kind(&missing).as_deref(), Some("unknown-job"), "{missing}");

    let bye = net::client_request(&addr, &proto::shutdown_line(), 0).unwrap();
    assert!(proto::response_is_ok(&bye), "{bye}");
    daemon.join().unwrap().unwrap();
}

fn meta_checkpoint(workload: &str, sampler: &str, chains: usize, rvs: usize) -> Checkpoint {
    Checkpoint {
        seed: 1,
        steps: 10,
        best_objective: 0.0,
        best_x: vec![0; rvs],
        anneal: None,
        temper: None,
        workload: Some(workload.to_string()),
        sampler: Some(sampler.to_string()),
        chains: Some(chains),
    }
}

fn expect_mismatch(err: Mc2aError, what: &str) {
    match err {
        Mc2aError::CheckpointMismatch { what: got, run, checkpoint } => {
            assert_eq!(got, what);
            assert_ne!(run, checkpoint, "both sides must be reported");
        }
        other => panic!("expected CheckpointMismatch for {what}, got: {other}"),
    }
}

/// `--init-from` mismatches are typed errors naming both sides, and a
/// matching checkpoint resumes cleanly.
#[test]
fn init_from_checkpoint_rejects_mismatched_resume() {
    let rvs = mc2a::engine::registry::lookup("optsicom").unwrap().model.num_vars();
    let builder = || Engine::for_workload("optsicom").unwrap().steps(20).chains(2);

    let err = builder()
        .init_from_checkpoint(&meta_checkpoint("imageseg", "gumbel", 2, rvs))
        .unwrap_err();
    expect_mismatch(err, "workload");

    let err = builder()
        .init_from_checkpoint(&meta_checkpoint("optsicom", "cdf", 2, rvs))
        .unwrap_err();
    expect_mismatch(err, "sampler");

    let err = builder()
        .init_from_checkpoint(&meta_checkpoint("optsicom", "gumbel", 4, rvs))
        .unwrap_err();
    expect_mismatch(err, "chains");

    let err = builder()
        .init_from_checkpoint(&meta_checkpoint("optsicom", "gumbel", 2, rvs + 1))
        .unwrap_err();
    expect_mismatch(err, "model RVs");

    // A checkpoint saved before the metadata existed only has the RV
    // count to check; a matching one resumes and runs.
    let mut legacy = meta_checkpoint("optsicom", "gumbel", 2, rvs);
    legacy.workload = None;
    legacy.sampler = None;
    legacy.chains = None;
    let metrics = builder()
        .init_from_checkpoint(&legacy)
        .unwrap()
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(metrics.chains.len(), 2);

    // A non-default LUT shape round-trips through its canonical
    // `lut:SIZE:BITS` spec, and a pre-spec checkpoint that only wrote
    // the bare family name still matches the default LUT shape.
    use mc2a::mcmc::SamplerKind;
    let lut32 = SamplerKind::parse("lut:32:6").unwrap();
    builder()
        .sampler(lut32)
        .init_from_checkpoint(&meta_checkpoint("optsicom", "lut:32:6", 2, rvs))
        .unwrap();
    builder()
        .sampler(SamplerKind::parse("lut").unwrap())
        .init_from_checkpoint(&meta_checkpoint("optsicom", "lut", 2, rvs))
        .unwrap();
    let err = builder()
        .sampler(lut32)
        .init_from_checkpoint(&meta_checkpoint("optsicom", "lut:16:8", 2, rvs))
        .unwrap_err();
    expect_mismatch(err, "sampler");
}
