//! Integration tests for the annealing core and the observer-driven
//! adaptive controller: schedule clamping/validation, checkpoint
//! resume continuing the β ramp, lockstep-driver equivalence with the
//! fixed-ramp paths, cross-backend β-trajectory determinism, and the
//! adaptive-vs-fixed time-to-target acceptance run.

use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex};

use mc2a::energy::{EnergyModel, PottsGrid};
use mc2a::engine::{
    BatchedSoftwareBackend, ChainCtx, ChainObserver, ChainSpec, Engine, ExecutionBackend,
    Mc2aError, ObserverAction, ProgressEvent, SoftwareBackend,
};
use mc2a::isa::HwConfig;
use mc2a::mcmc::{
    build_algo, AlgoKind, AnnealPolicy, BetaSchedule, Chain, FixedController, Mcmc, SamplerKind,
    StepStats,
};
use mc2a::rng::Rng;

// ------------------------------------------------------------ schedules

#[test]
fn geometric_cooling_terminates_exactly_at_target() {
    // The regression: a cooling schedule (`rate < 1`) used to sail
    // straight past `to` because of a wrong-sided `.min(to)` clamp.
    let cool = BetaSchedule::Geometric {
        from: 4.0,
        to: 0.5,
        rate: 0.5,
    };
    assert_eq!(cool.beta(0), 4.0);
    let mut prev = f32::INFINITY;
    for t in 0..300 {
        let b = cool.beta(t);
        assert!(b <= prev, "not monotone at t={t}: {b} > {prev}");
        assert!(b >= 0.5, "overshot the target at t={t}: {b}");
        prev = b;
    }
    assert_eq!(cool.beta(3), 0.5, "did not terminate at `to`");
    assert_eq!(cool.beta(299), 0.5, "did not hold at `to`");
}

#[test]
fn schedules_move_monotonically_toward_to_and_clamp() {
    // Direction-agnostic property over a grid of configurations:
    // β moves from `from` toward `to` without ever overshooting, and
    // linear/geometric ramps eventually reach `to` exactly.
    let cases = [
        BetaSchedule::Linear { from: 0.1, to: 2.0, steps: 40 },
        BetaSchedule::Linear { from: 2.0, to: 0.1, steps: 40 },
        BetaSchedule::Linear { from: 1.0, to: 1.0, steps: 7 },
        BetaSchedule::Geometric { from: 0.1, to: 2.0, rate: 1.3 },
        BetaSchedule::Geometric { from: 2.0, to: 0.1, rate: 0.7 },
        BetaSchedule::Geometric { from: 0.5, to: 8.0, rate: 2.0 },
        BetaSchedule::Geometric { from: 8.0, to: 0.5, rate: 0.25 },
    ];
    for s in cases {
        s.validate().expect("grid case must be valid");
        let (from, to) = match s {
            BetaSchedule::Linear { from, to, .. } => (from, to),
            BetaSchedule::Geometric { from, to, .. } => (from, to),
            BetaSchedule::Constant(b) => (b, b),
        };
        let (lo, hi) = (from.min(to), from.max(to));
        assert_eq!(s.beta(0), from, "{s:?}: wrong start");
        let mut prev = s.beta(0);
        for t in 1..500 {
            let b = s.beta(t);
            assert!((lo..=hi).contains(&b), "{s:?}: β out of range at t={t}: {b}");
            if from <= to {
                assert!(b >= prev, "{s:?}: not non-decreasing at t={t}");
            } else {
                assert!(b <= prev, "{s:?}: not non-increasing at t={t}");
            }
            prev = b;
        }
        assert_eq!(s.beta(499), to, "{s:?}: never reached `to`");
    }
}

#[test]
fn builder_rejects_degenerate_schedules() {
    let m = PottsGrid::new(3, 3, 2, 0.5);
    for bad in [
        BetaSchedule::Geometric { from: 1.0, to: 2.0, rate: 0.0 },
        BetaSchedule::Geometric { from: 1.0, to: 2.0, rate: -2.0 },
        BetaSchedule::Geometric { from: 0.0, to: 2.0, rate: 1.5 },
        BetaSchedule::Constant(f32::NAN),
    ] {
        assert!(
            matches!(
                Engine::for_model(&m).schedule(bad).build(),
                Err(Mc2aError::InvalidConfig(_))
            ),
            "builder accepted {bad:?}"
        );
    }
}

// ------------------------------------------------------- resume offsets

/// Transition-kernel wrapper that records every β it is stepped with.
struct BetaRecorder {
    inner: Box<dyn Mcmc>,
    seen: Arc<Mutex<Vec<f32>>>,
}

impl Mcmc for BetaRecorder {
    fn step(
        &mut self,
        model: &dyn EnergyModel,
        x: &mut [u32],
        beta: f32,
        rng: &mut Rng,
    ) -> StepStats {
        self.seen.lock().unwrap().push(beta);
        self.inner.step(model, x, beta, rng)
    }

    fn name(&self) -> &'static str {
        "beta-recorder"
    }
}

#[test]
fn chain_resume_consumes_the_continuous_beta_tail() {
    // One 2N-step run bit-compared against "N steps → checkpoint →
    // N steps with the schedule clock offset": the resumed chain must
    // consume exactly the second half of the continuous β sequence.
    let m = PottsGrid::new(4, 4, 2, 0.5);
    let schedule = BetaSchedule::Geometric {
        from: 0.2,
        to: 5.0,
        rate: 1.05,
    };
    let n = 40usize;
    let record = |offset: usize, steps: usize| -> Vec<f32> {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let algo = Box::new(BetaRecorder {
            inner: build_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1),
            seen: Arc::clone(&seen),
        });
        let mut chain = Chain::new(&m, algo, schedule, 9);
        chain.set_step_offset(offset);
        chain.run(steps);
        let out = seen.lock().unwrap().clone();
        out
    };
    let continuous = record(0, 2 * n);
    let resumed = record(n, n);
    assert_eq!(continuous.len(), 2 * n);
    assert_eq!(
        resumed,
        continuous[n..],
        "resumed ramp did not continue at the checkpoint step"
    );
    // The regression this pins: without the offset the resumed chain
    // replays the ramp head instead of its tail.
    assert_ne!(resumed, continuous[..n], "schedule is degenerate");
}

/// One recorded progress event: (chain id, step, β, objective).
type Event = (usize, usize, f32, f64);

/// Observer capturing every progress event's (chain, step, β,
/// objective) for trajectory comparisons.
#[derive(Clone, Default)]
struct EventTrace {
    events: Arc<Mutex<Vec<Event>>>,
}

impl ChainObserver for EventTrace {
    fn on_progress(&mut self, e: &ProgressEvent) -> ObserverAction {
        self.events
            .lock()
            .unwrap()
            .push((e.chain_id, e.step, e.beta, e.objective));
        ObserverAction::Continue
    }
}

#[test]
fn engine_resume_continues_the_ramp_on_every_software_backend() {
    let m = PottsGrid::new(5, 5, 2, 0.5);
    let schedule = BetaSchedule::Linear {
        from: 0.2,
        to: 3.0,
        steps: 80,
    };
    let run = |batched: bool, offset: usize, steps: usize| -> Vec<(usize, usize, f32, f64)> {
        let trace = EventTrace::default();
        let events = Arc::clone(&trace.events);
        let mut b = Engine::for_model(&m)
            .algo(AlgoKind::Gibbs)
            .schedule(schedule)
            .schedule_offset(offset)
            .steps(steps)
            .chains(1)
            .seed(5)
            .observe_every(10)
            .observer(Box::new(trace));
        if batched {
            b = b.batched();
        }
        b.build().unwrap().run().unwrap();
        let out = events.lock().unwrap().clone();
        out
    };
    for batched in [false, true] {
        let full = run(batched, 0, 100);
        let tail = run(batched, 50, 50);
        assert_eq!(full.len(), 10, "batched={batched}");
        assert_eq!(tail.len(), 5, "batched={batched}");
        // Steps are run-local (10..50) but the β values must be the
        // global-clock tail of the continuous run.
        let full_betas: Vec<f32> = full[5..].iter().map(|e| e.2).collect();
        let tail_betas: Vec<f32> = tail.iter().map(|e| e.2).collect();
        assert_eq!(tail_betas, full_betas, "batched={batched}: ramp restarted");
    }
}

// ------------------------------------------- lockstep driver equivalence

fn plain_ctx(stop: &AtomicBool) -> ChainCtx<'_> {
    ChainCtx {
        stop,
        events: None,
        restart: None,
    }
}

#[test]
fn adaptive_driver_with_fixed_controller_matches_fixed_software_path() {
    let m = PottsGrid::new(6, 5, 3, 0.7);
    let schedule = BetaSchedule::Linear {
        from: 0.3,
        to: 2.0,
        steps: 50,
    };
    let spec = ChainSpec {
        algo: AlgoKind::Gibbs,
        sampler: SamplerKind::Gumbel,
        schedule,
        beta_offset: 0,
        steps: 60,
        seed: 0xFEED,
        pas_flips: 1,
        observe_every: 7,
        init_state: None,
    };
    let stop = AtomicBool::new(false);
    let ctx = plain_ctx(&stop);
    let fixed = SoftwareBackend.run_chains(&m, &spec, 4, &ctx).unwrap();
    for backend in [
        Box::new(SoftwareBackend) as Box<dyn ExecutionBackend>,
        Box::new(BatchedSoftwareBackend::new(3)),
    ] {
        let mut controller = FixedController::new(schedule);
        let driven = backend
            .run_chains_adaptive(&m, &spec, 4, &ctx, &mut controller)
            .unwrap();
        assert_eq!(driven.len(), fixed.len());
        for (a, b) in fixed.iter().zip(&driven) {
            assert_eq!(a.chain_id, b.chain_id);
            assert_eq!(a.steps, b.steps, "{}", backend.name());
            assert_eq!(a.best_x, b.best_x, "{}", backend.name());
            assert_eq!(a.best_objective, b.best_objective, "{}", backend.name());
            assert_eq!(a.objective_trace, b.objective_trace, "{}", backend.name());
            assert_eq!(a.marginal0, b.marginal0, "{}", backend.name());
        }
    }
}

#[test]
fn adaptive_driver_with_fixed_controller_matches_fixed_accelerator_path() {
    use mc2a::engine::AcceleratorBackend;
    let m = PottsGrid::new(4, 4, 2, 0.6);
    let schedule = BetaSchedule::Linear {
        from: 0.2,
        to: 1.5,
        steps: 30,
    };
    let spec = ChainSpec {
        algo: AlgoKind::BlockGibbs,
        sampler: SamplerKind::Gumbel,
        schedule,
        beta_offset: 0,
        steps: 20,
        seed: 0xACC,
        pas_flips: 1,
        observe_every: 7,
        init_state: None,
    };
    let backend = AcceleratorBackend::new(HwConfig::fig10_toy());
    let stop = AtomicBool::new(false);
    let ctx = plain_ctx(&stop);
    let fixed = backend.run_chains(&m, &spec, 2, &ctx).unwrap();
    let mut controller = FixedController::new(schedule);
    let driven = backend
        .run_chains_adaptive(&m, &spec, 2, &ctx, &mut controller)
        .unwrap();
    for (a, b) in fixed.iter().zip(&driven) {
        assert_eq!(a.best_x, b.best_x, "final accelerator state diverged");
        assert_eq!(a.marginal0, b.marginal0);
        assert_eq!(a.objective_trace, b.objective_trace);
        let (ra, rb) = (a.sim.as_ref().unwrap(), b.sim.as_ref().unwrap());
        assert_eq!(ra.cycles, rb.cycles);
        assert_eq!(ra.samples, rb.samples);
        assert_eq!(ra.iterations, rb.iterations);
    }
}

// ------------------------------------------------ adaptive determinism

#[test]
fn adaptive_beta_trajectory_is_bit_identical_across_software_backends() {
    // Satellite: same seed + same observer cadence ⇒ the adaptive
    // controller makes the same decisions on the scalar and batched
    // backends, over registry workloads covering both the batched
    // kernels (Block Gibbs) and the scalar fallback (PAS).
    for wname in ["earthquake", "maxcut"] {
        let run = |batched: bool| -> Vec<(usize, usize, f32, f64)> {
            let trace = EventTrace::default();
            let events = Arc::clone(&trace.events);
            let mut b = Engine::for_workload(wname)
                .unwrap()
                .schedule(BetaSchedule::Geometric {
                    from: 0.2,
                    to: 4.0,
                    rate: 1.05,
                })
                .adaptive(AnnealPolicy::Reheat)
                .steps(60)
                .chains(4)
                .seed(0xD15C)
                .observe_every(10)
                .observer(Box::new(trace));
            if batched {
                b = b.batched().batch(2);
            }
            b.build().unwrap().run().unwrap();
            let out = events.lock().unwrap().clone();
            out
        };
        let scalar = run(false);
        let batched = run(true);
        assert!(!scalar.is_empty(), "{wname}: no events");
        assert_eq!(
            scalar, batched,
            "{wname}: adaptive trajectory diverged across backends"
        );
    }
}

// ------------------------------------------------- acceptance: adaptive

#[test]
fn adaptive_matches_fixed_best_within_the_same_budget() {
    // Acceptance: on at least one registry COP workload (seeded, small
    // budget), adaptive annealing reaches the fixed schedule's best
    // objective within the fixed schedule's own step budget. The fixed
    // baseline is an aggressive geometric quench that freezes the
    // chains early — the trap the reheat controller exists to escape.
    let schedule = BetaSchedule::Geometric {
        from: 0.1,
        to: 6.0,
        rate: 1.1,
    };
    let budget = 400usize;
    let mut wins = Vec::new();
    for wname in ["maxcut", "maxclique"] {
        for seed in [3u64, 7, 11] {
            let run = |policy: Option<AnnealPolicy>| -> f64 {
                let mut b = Engine::for_workload(wname)
                    .unwrap()
                    .algo(AlgoKind::Mh)
                    .schedule(schedule)
                    .steps(budget)
                    .chains(4)
                    .seed(seed)
                    .observe_every(20);
                if let Some(p) = policy {
                    b = b.adaptive(p);
                }
                let metrics = b.build().unwrap().run().unwrap();
                assert!(metrics.chains.iter().all(|c| c.steps == budget));
                metrics.best_objective()
            };
            let fixed = run(None);
            let adaptive = run(Some(AnnealPolicy::Reheat));
            if adaptive >= fixed {
                wins.push((wname, seed, fixed, adaptive));
            }
        }
    }
    assert!(
        !wins.is_empty(),
        "adaptive annealing never matched the fixed best within the budget"
    );
}

// ------------------------------------------------------ backend support

#[test]
fn adaptive_runs_on_the_accelerator_backends() {
    // Single-core simulator backend.
    let m = PottsGrid::new(4, 4, 2, 0.6);
    let metrics = Engine::for_model(&m)
        .schedule(BetaSchedule::Linear {
            from: 0.2,
            to: 2.0,
            steps: 30,
        })
        .adaptive(AnnealPolicy::Plateau)
        .steps(24)
        .chains(2)
        .observe_every(6)
        .accelerator(HwConfig::fig10_toy())
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(metrics.chains.len(), 2);
    for c in &metrics.chains {
        assert_eq!(c.steps, 24);
        assert!(c.sim.as_ref().unwrap().cycles > 0);
    }
    // Sharded multi-core backend (2 cores, Block Gibbs workload).
    let metrics = Engine::for_workload("earthquake")
        .unwrap()
        .schedule(BetaSchedule::Linear {
            from: 0.5,
            to: 2.0,
            steps: 20,
        })
        .adaptive(AnnealPolicy::Reheat)
        .steps(24)
        .chains(2)
        .observe_every(6)
        .cores(2)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(metrics.chains.len(), 2);
    for c in &metrics.chains {
        assert_eq!(c.steps, 24);
        assert!(c.multicore.is_some(), "no multi-core report");
    }
}

// -------------------------------------------------- builder + checkpoint

#[test]
fn adaptive_builder_validation() {
    let m = PottsGrid::new(4, 4, 2, 0.5);
    // Mutually exclusive with cold-chain restarts.
    assert!(matches!(
        Engine::for_model(&m)
            .chains(2)
            .adaptive(AnnealPolicy::Reheat)
            .restart_on_stagnation(1.1, 2)
            .build(),
        Err(Mc2aError::InvalidConfig(_))
    ));
    // Controller state without a controller.
    assert!(matches!(
        Engine::for_model(&m).anneal_state(vec![0.0; 8]).build(),
        Err(Mc2aError::InvalidConfig(_))
    ));
    // Malformed controller state.
    assert!(matches!(
        Engine::for_model(&m)
            .adaptive(AnnealPolicy::Reheat)
            .anneal_state(vec![1.0, 2.0])
            .build(),
        Err(Mc2aError::InvalidConfig(_))
    ));
    // Well-formed adaptive config builds.
    assert!(Engine::for_model(&m).adaptive(AnnealPolicy::Plateau).build().is_ok());
}

#[test]
fn adaptive_resume_restores_controller_memory() {
    let m = PottsGrid::new(5, 5, 2, 0.6);
    let schedule = BetaSchedule::Linear {
        from: 0.1,
        to: 2.5,
        steps: 120,
    };
    let mut first = Engine::for_model(&m)
        .schedule(schedule)
        .adaptive(AnnealPolicy::Reheat)
        .steps(60)
        .chains(2)
        .seed(21)
        .observe_every(10)
        .build()
        .unwrap();
    first.run().unwrap();
    let state = first.anneal_state().expect("adaptive run has state");
    assert!(first.anneal_describe().unwrap().starts_with("adaptive"));
    // Resume: ramp offset + restored controller memory both accepted,
    // and the continuation runs to completion.
    let metrics = Engine::for_model(&m)
        .schedule(schedule)
        .schedule_offset(60)
        .adaptive(AnnealPolicy::Reheat)
        .anneal_state(state)
        .steps(30)
        .chains(2)
        .seed(22)
        .observe_every(10)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(metrics.chains.iter().all(|c| c.steps == 30));
}
