//! Integration tests across compiler → simulator: the cycle-accurate
//! accelerator must (a) produce statistically correct samples, (b)
//! satisfy the compiler's hazard/conflict invariants on every Table I
//! workload, and (c) reproduce the paper's architectural behaviors
//! (BG ≫ sequential Gibbs, spatial-mode PAS cycle counts, ISA
//! encode/decode round-trips of real programs).

use mc2a::compiler::{compile, validate_program};
use mc2a::energy::{EnergyModel, PottsGrid};
use mc2a::isa::{CtrlType, HwConfig, InstrLayout, Semantics};
use mc2a::mcmc::{build_algo, AlgoKind, BetaSchedule, Chain, SamplerKind};
use mc2a::sim::Simulator;
use mc2a::workloads;

/// THE hardware-correctness test: accelerator marginals must match the
/// software chain (same LUT sampler) on the earthquake posterior.
#[test]
fn sim_marginals_match_software() {
    let net = workloads::earthquake();
    let exact = net.exact_marginal(2);
    let hw = HwConfig::paper_default();
    let program = compile(&net, AlgoKind::BlockGibbs, &hw, 1).unwrap();
    let mut sim = Simulator::new(hw, &net, 1, 0x51B);
    let _ = sim.run(&program, 120_000);
    let hw_marg = sim.marginal(2);
    assert!(
        (hw_marg[1] - exact[1]).abs() < 0.02,
        "accelerator {} vs exact {}",
        hw_marg[1],
        exact[1]
    );

    let a = build_algo(
        AlgoKind::BlockGibbs,
        SamplerKind::GumbelLut { size: 16, bits: 8 },
        &net,
        1,
    );
    let mut chain = Chain::new(&net, a, BetaSchedule::Constant(1.0), 0x51B);
    chain.run(120_000);
    let sw_marg = chain.marginal(2);
    assert!(
        (hw_marg[1] - sw_marg[1]).abs() < 0.02,
        "accelerator {} vs software {}",
        hw_marg[1],
        sw_marg[1]
    );
}

/// Ising phase behavior on the accelerator: cold chain magnetizes.
#[test]
fn sim_ising_orders_when_cold() {
    let m = PottsGrid::new(16, 16, 2, 1.0);
    let hw = HwConfig::paper_default();
    let program = compile(&m, AlgoKind::BlockGibbs, &hw, 1).unwrap();
    let mut sim = Simulator::new(hw, &m, 1, 0xC01D);
    sim.set_beta(2.0);
    // start all-up
    for v in sim.x.iter_mut() {
        *v = 1;
    }
    let _ = sim.run(&program, 300);
    let ones = sim.x.iter().filter(|&&v| v == 1).count();
    assert!(ones > 230, "magnetization lost: {ones}/256");
}

/// Compiler invariants hold for every workload × algorithm × config.
#[test]
fn compiled_suite_passes_validation() {
    for hw in [HwConfig::fig10_toy(), HwConfig::paper_default()] {
        for wl in workloads::suite_small() {
            let algos = [AlgoKind::Gibbs, AlgoKind::BlockGibbs, AlgoKind::Pas];
            for algo in algos {
                let p = compile(wl.model.as_ref(), algo, &hw, wl.pas_flips).unwrap();
                let coverage = !matches!(algo, AlgoKind::Pas);
                let v = validate_program(&p, wl.model.as_ref(), &hw, coverage);
                assert!(v.is_empty(), "{} {:?}: {:?}", wl.name, algo, &v[..v.len().min(3)]);
            }
        }
    }
}

/// Block Gibbs must be far faster than sequential Gibbs in cycles on a
/// parallel-friendly grid (the Fig. 4 / Fig. 10b story).
#[test]
fn block_gibbs_beats_sequential_in_cycles() {
    let m = PottsGrid::new(16, 16, 2, 1.0);
    let hw = HwConfig::paper_default();
    let cycles = |algo| {
        let p = compile(&m, algo, &hw, 1).unwrap();
        let mut sim = Simulator::new(hw, &m, 1, 1);
        sim.run(&p, 10).cycles
    };
    let bg = cycles(AlgoKind::BlockGibbs);
    let seq = cycles(AlgoKind::Gibbs);
    assert!(
        seq as f64 / bg as f64 > 10.0,
        "sequential {seq} vs block {bg} cycles"
    );
}

/// Spatial-mode PAS sampling cycles follow the Fig. 10(c) formula:
/// L × ceil(n_moves / S) Sample instructions.
#[test]
fn pas_sample_phase_matches_fig10c() {
    let wl = workloads::wl_maxcut_optsicom(); // 125 nodes → 250 moves
    let hw = HwConfig::paper_default(); // S = 64
    let l = 8;
    let p = compile(wl.model.as_ref(), AlgoKind::Pas, &hw, l).unwrap();
    let h = p.body_histogram();
    let n_moves = 250usize;
    assert_eq!(
        h[&CtrlType::Sample],
        l * n_moves.div_ceil(hw.s),
        "Sample instruction count"
    );
}

/// Real compiled programs round-trip through the dense ISA encoding.
#[test]
fn compiled_programs_encode_decode() {
    let hw = HwConfig::paper_default();
    let layout = InstrLayout::new(&hw);
    for wl in workloads::suite_small().iter().take(4) {
        let p = compile(wl.model.as_ref(), wl.algorithm, &hw, wl.pas_flips).unwrap();
        let enc = layout.encode(&p.body);
        let dec = layout.decode(&enc).expect("decode");
        assert_eq!(dec.len(), p.body.len());
        for (a, b) in p.body.iter().zip(&dec) {
            assert_eq!(a.ctrl, b.ctrl);
            assert_eq!(a.loads, b.loads);
            assert_eq!(a.routes, b.routes);
            assert_eq!(a.cu, b.cu);
            assert_eq!(a.su, b.su);
            assert_eq!(a.stores, b.stores);
        }
        // Instruction memory footprint sanity: a B=320-slot Load bundle
        // is inherently ~1.8 kB (320 slots × 45 bits); the dense pack
        // must stay under the naive byte-aligned encoding (~2.5 kB).
        let bytes_per_instr = enc.bit_len as f64 / 8.0 / p.body.len() as f64;
        assert!(bytes_per_instr < 2048.0, "{}: {bytes_per_instr} B/instr", wl.name);
    }
}

/// Utilization ordering: the MRF (massive parallelism) must use the CU
/// better than the tiny Bayes net (§V-E: "higher hardware utilization
/// because of more parallelizable RVs").
#[test]
fn utilization_scales_with_parallelism() {
    let hw = HwConfig::paper_default();
    let grid = PottsGrid::new(32, 32, 2, 1.0);
    let p1 = compile(&grid, AlgoKind::BlockGibbs, &hw, 1).unwrap();
    let mut s1 = Simulator::new(hw, &grid, 1, 1);
    let u_grid = s1.run(&p1, 5).cu_utilization();

    let net = workloads::earthquake();
    let p2 = compile(&net, AlgoKind::BlockGibbs, &hw, 1).unwrap();
    let mut s2 = Simulator::new(hw, &net, 1, 1);
    let u_net = s2.run(&p2, 5).cu_utilization();
    assert!(
        u_grid > u_net,
        "grid util {u_grid} should exceed bayes-net util {u_net}"
    );
}

/// Every functional commit in a compiled program has hardware work
/// attached (no "ghost" updates the timing model doesn't account for).
#[test]
fn commits_carry_hardware_work() {
    let hw = HwConfig::paper_default();
    for wl in workloads::suite_small() {
        let p = compile(wl.model.as_ref(), wl.algorithm, &hw, wl.pas_flips).unwrap();
        for i in &p.body {
            if matches!(i.sem, Semantics::UpdateRvs(_)) {
                assert!(i.cu.is_some() && i.su.is_some(), "{}: bare commit", wl.name);
                assert!(!i.stores.is_empty(), "{}: commit without store", wl.name);
            }
        }
    }
}

/// Scaling sanity: more SU/CU lanes (up to the parallelism limit) must
/// not slow any workload down.
#[test]
fn bigger_hardware_is_never_slower() {
    let m = PottsGrid::new(16, 16, 2, 1.0);
    let small = HwConfig::fig10_toy();
    let big = HwConfig::paper_default();
    let cycles = |hw: HwConfig| {
        let p = compile(&m, AlgoKind::BlockGibbs, &hw, 1).unwrap();
        let mut sim = Simulator::new(hw, &m, 1, 1);
        sim.run(&p, 10).cycles
    };
    assert!(cycles(big) < cycles(small));
}
