//! Integration tests for the replica-exchange (parallel tempering)
//! subsystem: builder validation, per-replica β assignment, swap-rate
//! and round-trip diagnostics, cross-backend bit-identity of tempered
//! trajectories, checkpoint round trips of the ladder + swap history,
//! and the tempered-vs-single-β time-to-target acceptance run.

use std::sync::{Arc, Mutex};

use mc2a::coordinator::ChainResult;
use mc2a::energy::PottsGrid;
use mc2a::engine::{
    ChainCtx, ChainObserver, ChainSpec, Checkpoint, Engine, ExecutionBackend, Mc2aError,
    ObserverAction, ProgressEvent,
};
use mc2a::isa::HwConfig;
use mc2a::mcmc::{AlgoKind, AnnealPolicy, BetaSchedule, Ladder, SamplerKind};

fn ladder4() -> Ladder {
    Ladder::explicit(vec![0.25, 0.5, 1.0, 2.0])
}

// ------------------------------------------------------- builder rules

#[test]
fn builder_rejects_degenerate_tempering_configs() {
    let m = PottsGrid::new(4, 4, 2, 0.5);
    fn expect_invalid(b: mc2a::EngineBuilder<'_>, what: &str) {
        match b.build() {
            Err(Mc2aError::InvalidConfig(_)) => {}
            other => panic!("{what}: expected InvalidConfig, got ok={:?}", other.is_ok()),
        }
    }
    // `--temper 1`: a one-rung ladder has nothing to swap with.
    expect_invalid(
        Engine::for_model(&m).chains(1).tempering(Ladder::explicit(vec![1.0])),
        "one-rung ladder",
    );
    // Non-monotone explicit ladder.
    expect_invalid(
        Engine::for_model(&m).chains(2).tempering(Ladder::explicit(vec![2.0, 1.0])),
        "non-monotone ladder",
    );
    // More rungs than chains.
    expect_invalid(
        Engine::for_model(&m).chains(2).tempering(ladder4()),
        "K > chains",
    );
    // Chains not a multiple of K (no partial ensembles).
    expect_invalid(
        Engine::for_model(&m).chains(6).tempering(ladder4()),
        "chains % K != 0",
    );
    // Tempering and adaptive annealing both want to own β.
    expect_invalid(
        Engine::for_model(&m)
            .chains(4)
            .tempering(ladder4())
            .adaptive(AnnealPolicy::Reheat),
        "temper + adaptive",
    );
    // Tempering replaces the β schedule.
    expect_invalid(
        Engine::for_model(&m)
            .chains(4)
            .tempering(ladder4())
            .schedule(BetaSchedule::Linear { from: 0.1, to: 2.0, steps: 50 }),
        "temper + non-constant schedule",
    );
    // Swap cadence of 0 is meaningless.
    expect_invalid(
        Engine::for_model(&m).chains(4).tempering(ladder4()).swap_every(0),
        "swap_every 0",
    );
    // Tempering knobs without tempering(ladder).
    expect_invalid(Engine::for_model(&m).chains(4).swap_every(5), "swap_every alone");
    expect_invalid(
        Engine::for_model(&m).chains(4).temper_adapt(0.3),
        "temper_adapt alone",
    );
    // Adaptive re-spacing needs a meaningful target rate.
    for bad_rate in [0.0, 1.0, -0.3, 1.5, f64::NAN] {
        expect_invalid(
            Engine::for_model(&m).chains(4).tempering(ladder4()).temper_adapt(bad_rate),
            "bad swap-target rate",
        );
    }
    assert!(Engine::for_model(&m)
        .chains(4)
        .tempering(ladder4())
        .temper_adapt(0.3)
        .build()
        .is_ok());
    // Restart and tempering are mutually exclusive.
    expect_invalid(
        Engine::for_model(&m)
            .chains(4)
            .tempering(ladder4())
            .restart_on_stagnation(1.1, 3),
        "temper + restart",
    );
    // A valid configuration builds.
    assert!(Engine::for_model(&m).chains(4).tempering(ladder4()).build().is_ok());
    assert!(Engine::for_model(&m).chains(8).tempering(ladder4()).build().is_ok());
}

#[test]
fn error_messages_name_the_offending_flag_combination() {
    let m = PottsGrid::new(4, 4, 2, 0.5);
    fn msg(b: mc2a::EngineBuilder<'_>) -> String {
        match b.build() {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected an error"),
        }
    }
    let s = msg(Engine::for_model(&m).chains(1).tempering(Ladder::explicit(vec![1.0])));
    assert!(s.contains("at least 2 rungs"), "{s}");
    let s = msg(Engine::for_model(&m).chains(2).tempering(Ladder::explicit(vec![2.0, 1.0])));
    assert!(s.contains("strictly increasing"), "{s}");
    let s = msg(
        Engine::for_model(&m)
            .chains(4)
            .tempering(ladder4())
            .adaptive(AnnealPolicy::Plateau),
    );
    assert!(s.contains("mutually exclusive"), "{s}");
    let s = msg(Engine::for_model(&m).chains(2).tempering(ladder4()));
    assert!(s.contains("chains ≥ K"), "{s}");
}

// ----------------------------------------------- default trait surface

struct NoTemperBackend;

impl ExecutionBackend for NoTemperBackend {
    fn name(&self) -> &'static str {
        "no-temper"
    }

    fn run_chain(
        &self,
        _model: &dyn mc2a::energy::EnergyModel,
        _spec: &ChainSpec,
        _chain_id: usize,
        _ctx: &ChainCtx<'_>,
    ) -> Result<ChainResult, Mc2aError> {
        unreachable!("tempered run must not reach run_chain")
    }
}

#[test]
fn backends_without_tempering_support_reject_with_a_typed_error() {
    // The default trait impl (what the runtime backend inherits)
    // surfaces a typed error naming the backend.
    let m = PottsGrid::new(3, 3, 2, 0.5);
    let err = Engine::for_model(&m)
        .chains(2)
        .tempering(Ladder::explicit(vec![0.5, 1.0]))
        .backend(Box::new(NoTemperBackend))
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    let s = err.to_string();
    assert!(s.contains("no-temper") && s.contains("parallel tempering"), "{s}");
}

// ------------------------------------------------ tempered runs + diag

/// Observer recording every progress event.
#[derive(Default)]
struct EventTrace {
    events: Arc<Mutex<Vec<(usize, usize, f32, f64)>>>,
}

impl ChainObserver for EventTrace {
    fn on_progress(&mut self, e: &ProgressEvent) -> ObserverAction {
        self.events
            .lock()
            .unwrap()
            .push((e.chain_id, e.step, e.beta, e.objective));
        ObserverAction::Continue
    }
}

#[test]
fn tempered_software_run_reports_per_pair_swap_diagnostics() {
    let m = PottsGrid::new(5, 5, 2, 0.8);
    let trace = EventTrace::default();
    let events = Arc::clone(&trace.events);
    let metrics = Engine::for_model(&m)
        .algo(AlgoKind::Gibbs)
        .chains(8) // two ensembles of 4
        .steps(120)
        .seed(21)
        .tempering(ladder4())
        .swap_every(6)
        .observer(Box::new(trace))
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(metrics.chains.len(), 8);
    for c in &metrics.chains {
        assert_eq!(c.steps, 120);
        let t = c.tempering.as_ref().expect("tempered chain has a report");
        // Ensemble membership: chains 0..4 → first ensemble, 4..8 → second.
        assert_eq!(t.first_chain, (c.chain_id / 4) * 4);
        assert_eq!(t.betas.len(), 4);
        assert_eq!(t.pair_attempts.len(), 3);
        assert_eq!(t.pair_accepts.len(), 3);
        assert_eq!(t.round_trips.len(), 4);
        assert_eq!(t.rounds, 120 / 6);
        // Every pair was proposed: 20 rounds alternate even/odd.
        assert!(t.pair_attempts.iter().all(|&a| a > 0), "{:?}", t.pair_attempts);
        assert!(t.swap_rates().iter().all(|&r| (0.0..=1.0).contains(&r)));
    }
    // First observation segment: chain c of each ensemble still sits on
    // rung c % 4, so its reported β is the ladder rung.
    let events = events.lock().unwrap();
    let rungs = ladder4();
    for c in 0..8usize {
        let first = events
            .iter()
            .find(|(cid, _, _, _)| *cid == c)
            .expect("every chain emits events");
        assert_eq!(first.2, rungs.betas()[c % 4], "chain {c} first-segment β");
    }
}

#[test]
fn tempered_trajectories_are_bit_identical_across_software_backends() {
    // Satellite: the swap stream is Rng::fork(seed, SWAP_STREAM), so a
    // tempered run makes identical swap decisions on the scalar and
    // batched backends — and since swaps move temperatures, not
    // states, the full event stream matches bit-for-bit. Registry
    // workloads cover the batched kernels (Block Gibbs) and the
    // scalar PAS fallback.
    for wname in ["earthquake", "maxcut"] {
        let run = |batched: bool| -> (Vec<(usize, usize, f32, f64)>, Vec<f64>, Vec<u64>) {
            let trace = EventTrace::default();
            let events = Arc::clone(&trace.events);
            let mut b = Engine::for_workload(wname)
                .unwrap()
                .tempering(ladder4())
                .swap_every(5)
                .steps(60)
                .chains(4)
                .seed(0x7E12)
                .observer(Box::new(trace));
            if batched {
                b = b.batched().batch(2);
            }
            let metrics = b.build().unwrap().run().unwrap();
            let t = metrics.chains[0].tempering.clone().unwrap();
            let out = events.lock().unwrap().clone();
            (out, t.swap_rates(), t.round_trips)
        };
        let scalar = run(false);
        let batched = run(true);
        assert!(!scalar.0.is_empty(), "{wname}: no events");
        assert_eq!(scalar.0, batched.0, "{wname}: tempered events diverged");
        assert_eq!(scalar.1, batched.1, "{wname}: swap rates diverged");
        assert_eq!(scalar.2, batched.2, "{wname}: round trips diverged");
    }
}

#[test]
fn tempered_accelerator_and_multicore_runs_complete() {
    let m = PottsGrid::new(4, 4, 2, 0.6);
    let ladder = Ladder::explicit(vec![0.5, 1.0]);
    for multicore in [false, true] {
        let mut b = Engine::for_model(&m)
            .algo(AlgoKind::BlockGibbs)
            .chains(2)
            .steps(30)
            .seed(5)
            .tempering(ladder.clone())
            .swap_every(5);
        b = if multicore {
            b.multicore(HwConfig::fig10_toy())
        } else {
            b.accelerator(HwConfig::fig10_toy())
        };
        let metrics = b.build().unwrap().run().unwrap();
        assert_eq!(metrics.chains.len(), 2);
        for c in &metrics.chains {
            let rep = c.sim.as_ref().expect("sim report");
            assert!(rep.cycles > 0);
            assert_eq!(rep.iterations, 30);
            let t = c.tempering.as_ref().expect("tempering report");
            assert_eq!(t.rounds, 6);
            assert!(t.pair_attempts[0] > 0);
        }
    }
}

// -------------------------------------------------- checkpoint resume

#[test]
fn temper_state_round_trips_through_builder_and_checkpoint() {
    let m = PottsGrid::new(5, 5, 2, 0.7);
    let build = |steps: usize| {
        Engine::for_model(&m)
            .algo(AlgoKind::Gibbs)
            .chains(4)
            .steps(steps)
            .seed(33)
            .tempering(ladder4())
            .swap_every(5)
            .temper_adapt(0.3)
            .build()
            .unwrap()
    };
    let mut engine = build(100);
    let metrics = engine.run().unwrap();
    let state = engine.temper_state().expect("tempered engine serializes state");
    assert_eq!(state[0], 1.0, "one ensemble");

    // Through the flat-JSON checkpoint.
    let ck = Checkpoint {
        seed: 33,
        steps: 100,
        best_objective: metrics.best_objective(),
        best_x: metrics.chains[0].best_x.clone(),
        anneal: None,
        temper: Some(state.clone()),
        workload: None,
        sampler: None,
        chains: None,
    };
    let parsed = Checkpoint::from_json(&ck.to_json()).unwrap();
    assert_eq!(parsed.temper.as_ref(), Some(&state));

    // Through the builder: restoring reproduces the serialized state
    // exactly (ladder, rung assignment, swap history, RNG position).
    let resumed = Engine::for_model(&m)
        .algo(AlgoKind::Gibbs)
        .chains(4)
        .steps(100)
        .seed(33)
        .tempering(ladder4())
        .swap_every(5)
        .temper_adapt(0.3)
        .schedule_offset(100)
        .temper_state(parsed.temper.clone().unwrap())
        .build()
        .unwrap();
    assert_eq!(resumed.temper_state().unwrap(), state);

    // Wrong-shape states are typed errors.
    assert!(matches!(
        Engine::for_model(&m)
            .chains(4)
            .tempering(ladder4())
            .temper_state(vec![2.0, 1.0])
            .build(),
        Err(Mc2aError::InvalidConfig(_))
    ));
}

#[test]
fn resumed_tempered_run_continues_the_swap_clock() {
    // The satellite's swap-schedule contract: swap rounds live on the
    // *global* step clock, so a run split in half performs exactly as
    // many swap rounds as the uninterrupted run — the tail rounds keep
    // the even/odd parity sequence (pinned bit-exactly at the
    // ReplicaExchange level in `mcmc::tempering`'s unit tests, where
    // the same energy tail reproduces identical decisions).
    let m = PottsGrid::new(5, 5, 2, 0.7);
    let run_half = |steps: usize, offset: usize, state: Option<Vec<f64>>| {
        let mut b = Engine::for_model(&m)
            .algo(AlgoKind::Gibbs)
            .chains(4)
            .steps(steps)
            .seed(71)
            .tempering(ladder4())
            .swap_every(7)
            .schedule_offset(offset);
        if let Some(s) = state {
            b = b.temper_state(s);
        }
        let mut engine = b.build().unwrap();
        engine.run().unwrap();
        engine.temper_state().unwrap()
    };
    // Uninterrupted: 140 steps ⇒ 20 swap rounds.
    let full = run_half(140, 0, None);
    // Split: 70 + 70 with the state carried across. The first half's
    // final segment (70 % 7 == 0) ends exactly on a boundary.
    let first = run_half(70, 0, None);
    let second = run_half(70, 70, Some(first));
    // Same number of swap rounds on the global clock. (state[2] is the
    // first ensemble's rounds counter: [ensembles, k, rounds, …].)
    assert_eq!(full[2], 20.0, "uninterrupted rounds");
    assert_eq!(second[2], 20.0, "resumed run lost swap rounds");
}

// ------------------------------------------- acceptance: time-to-best

#[test]
fn tempered_matches_single_beta_best_within_the_same_budget() {
    // Acceptance: on at least one registry COP workload (seeded, small
    // budget), replica exchange reaches the single-β run's best
    // objective within the single-β run's own step budget. The
    // baseline runs every chain at the cold target β — the greedy
    // regime that freezes into local optima; the ladder's hot rungs
    // exist to escape them.
    let budget = 400usize;
    let mut wins = Vec::new();
    for wname in ["maxcut", "maxclique"] {
        for seed in [3u64, 7, 11] {
            let single = Engine::for_workload(wname)
                .unwrap()
                .algo(AlgoKind::Mh)
                .schedule(BetaSchedule::Constant(4.0))
                .steps(budget)
                .chains(4)
                .seed(seed)
                .observe_every(20)
                .build()
                .unwrap()
                .run()
                .unwrap();
            let tempered = Engine::for_workload(wname)
                .unwrap()
                .algo(AlgoKind::Mh)
                .tempering(Ladder::geometric(0.2, 4.0, 4))
                .swap_every(20)
                .steps(budget)
                .chains(4)
                .seed(seed)
                .build()
                .unwrap()
                .run()
                .unwrap();
            assert!(tempered.chains.iter().all(|c| c.steps == budget));
            if tempered.best_objective() >= single.best_objective() {
                wins.push((wname, seed));
            }
        }
    }
    assert!(
        !wins.is_empty(),
        "replica exchange never matched the single-β best within the budget"
    );
}

#[test]
fn adaptive_ladder_respacing_keeps_a_valid_ladder() {
    let m = PottsGrid::new(5, 5, 2, 0.8);
    let metrics = Engine::for_model(&m)
        .algo(AlgoKind::Gibbs)
        .chains(4)
        .steps(300)
        .seed(13)
        .tempering(ladder4())
        .swap_every(3)
        .temper_adapt(0.3)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let t = metrics.chains[0].tempering.as_ref().unwrap();
    assert!(t.adapts >= 1, "re-spacing never fired");
    // Endpoints pinned, interior re-spaced but still a valid ladder.
    assert_eq!(t.betas[0], 0.25);
    assert_eq!(t.betas[3], 2.0);
    Ladder::explicit(t.betas.clone()).validate().unwrap();
}

// ---------------------------------------------- sampler-kind coverage

#[test]
fn tempering_works_with_every_batched_kernel() {
    let m = PottsGrid::new(4, 4, 3, 0.5);
    for (algo, sampler) in [
        (AlgoKind::Gibbs, SamplerKind::Gumbel),
        (AlgoKind::BlockGibbs, SamplerKind::Cdf),
        (AlgoKind::Mh, SamplerKind::Gumbel),
    ] {
        let metrics = Engine::for_model(&m)
            .algo(algo)
            .sampler(sampler)
            .chains(2)
            .steps(40)
            .seed(9)
            .tempering(Ladder::explicit(vec![0.5, 1.5]))
            .swap_every(4)
            .batched()
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(metrics.chains.len(), 2);
        assert!(metrics.chains[0].tempering.is_some(), "{algo:?}");
    }
}
